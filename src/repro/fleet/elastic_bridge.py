"""Elastic execution bridge: every scheduled migration runs (or is
faithfully simulated) as checkpoint → reshard → resume.

The planning layers (`fleet.policies`, `fleet.planner`) emit `Move`s; the
`MigrationExecutor` ledger turns each move into a `Transfer` occupying link
bandwidth over simulated time.  Before this bridge, that transfer was an
abstract blob of ``state_mb=64.0`` megabytes — the numbers meant nothing
physical.  The bridge gives the executor a pluggable **backend seam** that
maps every transfer onto the `runtime.elastic` flow:

  snapshot   pause/stream the job's state into a `ckpt` checkpoint
             (`ElasticBackend.snapshot` → `SnapshotInfo`: payload bytes,
             shard-file count, host-side serialize time)
  transfer   the checkpoint bytes cross the move's links — the executor
             ledger still owns fair-share contention, but the byte count
             now comes from the snapshot, not a flat constant
  restore    rebuild the job's `MeshPlan` over the destination's devices
             (`resize_mesh_plan` keeps model-parallel axes intact) and
             `reshard_restore` the checkpoint onto the new mesh, resuming
             at the recorded step

Backends:

* `FlatStateBackend` — the pre-bridge model, kept as an explicit object:
  every app ships ``state_mb`` MB, snapshot/restore are free.  Parity
  tests pin the simulated backend against it.
* `SimulatedElasticBackend` — derives transfer size and snapshot/restore
  phase times from *declared* checkpoint byte counts
  (`AppProfile.state_mb`, or an attached model via `train.state_shapes` +
  `ckpt.tree_nbytes`) and the `ckpt` shard layout (`shard_count`).  Apps
  with no declared state keep the flat fallback with zero host phases, so
  the paper scenarios' fleet fingerprints are bit-identical to
  `FlatStateBackend` — the bridge changes what the numbers *mean*, not
  what happens, until a job declares real state.
* `LiveElasticBackend` — the real thing, used when JAX devices are
  present: `ckpt.save` on snapshot, `reshard_restore` onto the rebuilt
  mesh on restore, source-checkpoint re-install on rollback.  Drives the
  demo (`examples/reconfiguration_demo.py`) and the multi-device smoke.

Rollback contract: when a destination dies mid-copy the executor calls
`ElasticBackend.rollback` — the source checkpoint taken at transfer start
is re-installed (live: reshard-restored onto the source mesh; simulated:
bookkept) and the job keeps/resumes running where it was.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

from repro.core.apps import PlacementRequest
from repro.core.migration import Move

if TYPE_CHECKING:  # jax-importing modules are deferred to call sites so the
    from repro.runtime.elastic import MeshPlan  # pure simulator stays light

MODE_PRECOPY = "precopy"
MODE_STOP_AND_COPY = "stop_and_copy"

#: Fraction of the copy a pre-copy migration replays as its final
#: dirty-page round (the only pause the source-side user sees).
DIRTY_PAGE_FRACTION = 0.05


def pipeline_downtime(mode: str, snapshot_s: float, transfer_s: float,
                      restore_s: float) -> float:
    """User-visible pause of one completed pipeline, by mode: pre-copy
    streams the snapshot and copy while the source keeps serving, pausing
    only for one dirty-page round plus the restore cutover; stop-and-copy
    pauses for the whole snapshot → copy → restore.  The one formula both
    the executor's records and `execute_move` use."""
    if mode == MODE_PRECOPY:
        return DIRTY_PAGE_FRACTION * transfer_s + restore_s
    return snapshot_s + transfer_s + restore_s


@dataclasses.dataclass(frozen=True)
class SnapshotInfo:
    """One taken snapshot: what the wire must carry and what the host paid.

    ``snapshot_s`` / ``restore_s`` are the host-side serialize and
    device_put phases (simulated: deterministic from byte count and shard
    layout; live: measured wall clock).  ``restore_s`` is the *estimate*
    the executor schedules with — `ElasticBackend.restore` returns the
    realized value."""

    req_id: int
    nbytes: int                 # checkpoint payload bytes
    mbits: float                # what the transfer occupies on the links
    n_shards: int               # ckpt shard files (restore opens each)
    snapshot_s: float
    restore_s: float
    path: Optional[str] = None  # live backend: the on-disk checkpoint
    mesh_shape: Optional[Tuple[int, ...]] = None  # source mesh at snapshot
    # Serving-workload state strategy ("drain" | "replay" | "kv-ship") the
    # backend chose for this snapshot; None for non-serving apps.  Threaded
    # by the executor onto the resulting `MigrationRecord`.
    strategy: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class MigrationPhases:
    """Per-phase timing of one executed migration (the quantities that
    flow into `fleet.telemetry.MigrationRecord` and BENCH_fleet.json)."""

    mode: str                   # MODE_PRECOPY | MODE_STOP_AND_COPY
    snapshot_s: float
    transfer_s: float
    restore_s: float
    downtime_s: float           # user-visible pause (mode-dependent subset)
    mbits: float

    @property
    def duration_s(self) -> float:
        return self.snapshot_s + self.transfer_s + self.restore_s


def _device_budget(move: Optional[Move], target_n: int) -> int:
    """Devices the move's destination can offer a mesh rebuild.

    Node capacity denominates *schedulable devices* on the fleets where
    mesh plans live (`core.cluster.build_fleet_topology`: capacity =
    chips), so it clamps the job's target size.  Sub-unit capacities
    (fractional FPGA shares, and any other non-count unit < 1) don't
    denominate devices — the job keeps its target size instead of
    crashing the resize with a zero-device mesh."""
    if move is None:
        return target_n
    cap = float(move.new.node.capacity)
    if cap < 1.0:
        return target_n
    return min(target_n, int(cap))


class ElasticBackend:
    """Seam between the migration ledger and the elastic-training runtime.

    The executor calls, in order: `snapshot` when a transfer starts (the
    byte count sizes the copy), `restore` when it completes (mesh rebuild
    + reshard-restore at the destination), `rollback` when the destination
    dies mid-copy (re-install the source checkpoint), and `release` when
    the app departs mid-migration.  `transfer_mbits` is the shared size
    model — `InstantExecutor` prices its schedules through the same
    method, so the two executors cannot drift."""

    name = "abstract"

    def transfer_mbits(self, request: PlacementRequest, move: Move) -> float:
        """Megabits a migration of ``request`` along ``move`` would copy."""
        raise NotImplementedError

    def predict_phases(self, request: PlacementRequest,
                       move: Optional[Move] = None) -> Tuple[float, float, float]:
        """Pure prediction of ``(mbits, snapshot_s, restore_s)`` for a
        hypothetical migration of ``request`` — what `snapshot` would
        report, **without** taking one (no registry mutation, no state
        retained).  The planner's cost model and the runtime's
        calibration ledger price moves through this, so planning can
        never perturb the executor's bookkeeping."""
        return (self.transfer_mbits(request, move), 0.0, 0.0)

    def snapshot(self, request: PlacementRequest, move: Move,
                 now: float) -> SnapshotInfo:
        """Checkpoint the job's state; returns what the wire must carry."""
        raise NotImplementedError

    def restore(self, request: PlacementRequest, move: Move,
                snap: SnapshotInfo, now: float) -> float:
        """Rebuild the mesh at the destination and reshard-restore the
        snapshot; returns the realized restore time in seconds."""
        raise NotImplementedError

    def rollback(self, request: PlacementRequest, move: Move,
                 snap: SnapshotInfo, now: float) -> None:
        """Destination failed mid-copy: re-install the source checkpoint
        so the job keeps/resumes running where it was."""
        raise NotImplementedError

    def release(self, req_id: int) -> None:
        """The app departed mid-migration; drop any retained snapshot."""


class FlatStateBackend(ElasticBackend):
    """The pre-bridge transfer model as an explicit backend: every app
    ships a flat ``state_mb`` MB, snapshot and restore are instantaneous.
    Kept so the simulated backend's fallback behavior can be pinned
    against it (fingerprint parity) and for callers that want the legacy
    semantics on purpose."""

    name = "flat"

    def __init__(self, state_mb: float = 64.0):
        self.state_mb = state_mb

    def transfer_mbits(self, request: PlacementRequest, move: Move) -> float:
        return self.state_mb * 8.0

    def snapshot(self, request: PlacementRequest, move: Move,
                 now: float) -> SnapshotInfo:
        return SnapshotInfo(
            req_id=request.req_id, nbytes=int(self.state_mb * 1e6),
            mbits=self.state_mb * 8.0, n_shards=1,
            snapshot_s=0.0, restore_s=0.0)

    def restore(self, request, move, snap, now) -> float:
        return 0.0

    def rollback(self, request, move, snap, now) -> None:
        pass


class SimulatedElasticBackend(ElasticBackend):
    """Faithful simulation of the checkpoint → reshard → resume pipeline.

    Transfer size comes from the job's *checkpoint byte count* — either an
    attached model (`attach_job(cfg=..., optimizer=...)` sizes the exact
    `train.state_shapes` tree through `ckpt.tree_nbytes`), explicit
    ``state_bytes``, or the app profile's declared ``state_mb``.  Host
    phase times follow the `ckpt` format: serialize/device_put at
    ``host_gbps`` plus ``per_shard_s`` per shard file (`ckpt.shard_count`
    of the payload), charged on both the snapshot and the restore side.

    Apps with no declared state fall back to ``default_state_mb`` with
    zero host phases — byte-identical to `FlatStateBackend`, which is what
    keeps the paper scenarios' fleet fingerprints unchanged.

    Mesh bookkeeping: a job attached with a `MeshPlan` gets its plan
    rebuilt on every restore via `resize_mesh_plan` toward the job's
    *attached* device count, clamped to the destination node's capacity —
    so a move onto a small slice shrinks the mesh and a later move back
    onto a big one grows it again (the hetero-expansion resize path) —
    and `mesh_plans[req_id]` always holds the job's current plan."""

    name = "simulated"

    def __init__(self, default_state_mb: float = 64.0,
                 host_gbps: float = 16.0, per_shard_s: float = 0.01):
        self.default_state_mb = default_state_mb
        self.host_gbps = host_gbps       # host-side serialize/device_put rate
        self.per_shard_s = per_shard_s   # per shard-file open/flush overhead
        self.mesh_plans: Dict[int, "MeshPlan"] = {}
        self.snapshots: Dict[int, SnapshotInfo] = {}
        # (req_id, dest_node_id, from_shape, to_shape) per completed restore
        self.restores: List[Tuple[int, Optional[str],
                                  Optional[Tuple[int, ...]],
                                  Optional[Tuple[int, ...]]]] = []
        self.rollbacks: List[int] = []
        self._job_bytes: Dict[int, int] = {}
        self._target_n: Dict[int, int] = {}   # attached (full-size) devices

    # ------------------------------------------------------------- registry
    def attach_job(self, req_id: int, *, state_bytes: Optional[int] = None,
                   cfg: Any = None, optimizer: Any = None,
                   mesh_plan: Optional[MeshPlan] = None) -> None:
        """Declare a training job behind ``req_id``: its checkpoint size
        (explicit bytes, or computed from the model's state tree) and
        optionally its device-mesh plan (rebuilt on every migration)."""
        if state_bytes is None and cfg is not None:
            from repro.ckpt import tree_nbytes      # deferred: pulls in jax
            from repro.train import state_shapes
            state_bytes = tree_nbytes(state_shapes(cfg, optimizer))
        if state_bytes is not None:
            self._job_bytes[req_id] = int(state_bytes)
        if mesh_plan is not None:
            self.mesh_plans[req_id] = mesh_plan
            self._target_n[req_id] = mesh_plan.n_devices

    def _state_nbytes(self, request: PlacementRequest) -> Optional[int]:
        nb = self._job_bytes.get(request.req_id)
        if nb is not None:
            return nb
        if request.app.state_mb is not None:
            return int(request.app.state_mb * 1e6)
        return None

    def _host_s(self, nbytes: int, n_shards: int) -> float:
        return nbytes * 8.0 / 1e9 / self.host_gbps + n_shards * self.per_shard_s

    # -------------------------------------------------------------- backend
    def transfer_mbits(self, request: PlacementRequest, move: Move) -> float:
        nb = self._state_nbytes(request)
        return self.default_state_mb * 8.0 if nb is None else nb * 8.0 / 1e6

    def predict_phases(self, request: PlacementRequest,
                       move: Optional[Move] = None) -> Tuple[float, float, float]:
        """Exactly the numbers `snapshot` would produce — same byte count,
        shard layout, and host-phase model — but read-only (nothing lands
        in ``snapshots``)."""
        nb = self._state_nbytes(request)
        if nb is None:
            return (self.default_state_mb * 8.0, 0.0, 0.0)
        from repro.ckpt import shard_count          # deferred: pulls in jax
        host = self._host_s(nb, shard_count(nb))
        return (nb * 8.0 / 1e6, host, host)

    def snapshot(self, request: PlacementRequest, move: Move,
                 now: float) -> SnapshotInfo:
        nb = self._state_nbytes(request)
        plan = self.mesh_plans.get(request.req_id)
        shape = plan.shape if plan is not None else None
        if nb is None:   # no declared state: legacy flat semantics
            snap = SnapshotInfo(
                req_id=request.req_id, nbytes=int(self.default_state_mb * 1e6),
                mbits=self.default_state_mb * 8.0, n_shards=1,
                snapshot_s=0.0, restore_s=0.0, mesh_shape=shape)
        else:
            from repro.ckpt import shard_count      # deferred: pulls in jax
            shards = shard_count(nb)
            host = self._host_s(nb, shards)
            snap = SnapshotInfo(
                req_id=request.req_id, nbytes=nb, mbits=nb * 8.0 / 1e6,
                n_shards=shards, snapshot_s=host, restore_s=host,
                mesh_shape=shape)
        self.snapshots[request.req_id] = snap
        return snap

    def restore(self, request: PlacementRequest, move: Move,
                snap: SnapshotInfo, now: float) -> float:
        plan = self.mesh_plans.get(request.req_id)
        dest = move.new.node.node_id if move is not None else None
        if plan is None:
            self.restores.append((request.req_id, dest, None, None))
        else:
            from repro.runtime.elastic import resize_mesh_plan
            # Resize toward the job's attached device count (so a move back
            # onto a big slice grows the mesh again), clamped to what the
            # destination offers.
            target = self._target_n.get(request.req_id, plan.n_devices)
            new_plan = resize_mesh_plan(plan, _device_budget(move, target))
            self.mesh_plans[request.req_id] = new_plan
            self.restores.append((request.req_id, dest, plan.shape, new_plan.shape))
        return snap.restore_s

    def rollback(self, request: PlacementRequest, move: Move,
                 snap: SnapshotInfo, now: float) -> None:
        # The snapshot taken at transfer start IS the source checkpoint —
        # it stays registered so the job resumes from it; the mesh plan
        # never changed (restore is what rebuilds it).
        self.rollbacks.append(request.req_id)

    def release(self, req_id: int) -> None:
        self.snapshots.pop(req_id, None)
        self._job_bytes.pop(req_id, None)
        self.mesh_plans.pop(req_id, None)
        self._target_n.pop(req_id, None)


# ------------------------------------------------------------- live backend
@dataclasses.dataclass
class LiveJob:
    """A real training job the live backend can checkpoint and rebuild."""

    ckpt_dir: str
    cfg: Any                    # ModelConfig
    optimizer: Any              # train.Optimizer
    plan: MeshPlan
    devices: Optional[list] = None   # default: jax.devices()
    state: Any = None           # live state to snapshot (None: reuse latest ckpt)
    step: int = 0


@dataclasses.dataclass
class ResumedJob:
    """What a restore hands back: everything needed to re-jit and resume."""

    state: Any
    step: int
    mesh: Any
    strategy: Any
    plan: MeshPlan


class LiveElasticBackend(ElasticBackend):
    """Execute migrations for real: `ckpt.save` on snapshot,
    `reshard_restore` onto the rebuilt destination mesh on restore,
    source-checkpoint re-install on rollback.  Phase times are measured
    wall clock (this backend runs *outside* the deterministic simulator —
    the demo and the live smoke drive it through `execute_move`).

    After a restore/rollback, ``resumed[req_id]`` holds the
    (state, step, mesh, strategy) the caller rebuilds its jitted step
    around."""

    name = "live"

    def __init__(self):
        self.jobs: Dict[int, LiveJob] = {}
        self.resumed: Dict[int, ResumedJob] = {}

    def register_job(self, req_id: int, ckpt_dir: str, cfg: Any,
                     optimizer: Any, mesh_plan: MeshPlan,
                     devices: Optional[list] = None) -> LiveJob:
        job = LiveJob(ckpt_dir, cfg, optimizer, mesh_plan, devices=devices)
        self.jobs[req_id] = job
        return job

    def update_state(self, req_id: int, state: Any, step: int) -> None:
        """Hand the backend the job's live state so `snapshot` can save it
        (otherwise snapshot reuses the latest committed checkpoint)."""
        job = self.jobs[req_id]
        job.state, job.step = state, step

    def _devices(self, job: LiveJob) -> list:
        if job.devices is not None:
            return list(job.devices)
        import jax
        return jax.devices()

    def transfer_mbits(self, request: PlacementRequest, move: Move) -> float:
        from repro.ckpt import checkpoint_nbytes, latest_checkpoint
        job = self.jobs.get(request.req_id)
        if job is not None:
            path = latest_checkpoint(job.ckpt_dir)
            if path is not None:
                nb, _ = checkpoint_nbytes(path)
                return nb * 8.0 / 1e6
        if request.app.state_mb is not None:
            return request.app.state_mb * 8.0
        return 64.0 * 8.0

    def snapshot(self, request: PlacementRequest, move: Move,
                 now: float) -> SnapshotInfo:
        from repro.ckpt import checkpoint_nbytes, latest_checkpoint, save
        job = self.jobs[request.req_id]
        t0 = time.perf_counter()
        if job.state is not None:
            path = save(job.ckpt_dir, job.step, job.state,
                        extra={"step": job.step})
        else:
            path = latest_checkpoint(job.ckpt_dir)
            if path is None:
                raise FileNotFoundError(
                    f"job {request.req_id}: no live state and no committed "
                    f"checkpoint under {job.ckpt_dir}")
        snapshot_s = time.perf_counter() - t0
        nbytes, shards = checkpoint_nbytes(path)
        return SnapshotInfo(
            req_id=request.req_id, nbytes=nbytes, mbits=nbytes * 8.0 / 1e6,
            n_shards=shards, snapshot_s=snapshot_s, restore_s=0.0,
            path=path, mesh_shape=job.plan.shape)

    def _reshard(self, job: LiveJob, plan: MeshPlan) -> Tuple[ResumedJob, float]:
        from repro.runtime.elastic import reshard_restore
        t0 = time.perf_counter()
        devices = self._devices(job)
        mesh = plan.build(devices)
        state, step, strat = reshard_restore(job.ckpt_dir, job.cfg,
                                             job.optimizer, mesh)
        job.state, job.step = state, step
        return ResumedJob(state, step, mesh, strat, plan), time.perf_counter() - t0

    def restore(self, request: PlacementRequest, move: Move,
                snap: SnapshotInfo, now: float) -> float:
        from repro.runtime.elastic import resize_mesh_plan
        job = self.jobs[request.req_id]
        n_dev = _device_budget(move, len(self._devices(job)))
        new_plan = resize_mesh_plan(job.plan, n_dev)
        resumed, restore_s = self._reshard(job, new_plan)
        job.plan = new_plan
        self.resumed[request.req_id] = resumed
        return restore_s

    def rollback(self, request: PlacementRequest, move: Move,
                 snap: SnapshotInfo, now: float) -> None:
        """Destination died: reshard-restore the source checkpoint onto the
        (unchanged) source mesh plan so the job resumes where it was."""
        job = self.jobs[request.req_id]
        self.resumed[request.req_id], _ = self._reshard(job, job.plan)

    def release(self, req_id: int) -> None:
        self.jobs.pop(req_id, None)
        self.resumed.pop(req_id, None)


# ------------------------------------------------------------ one-shot path
def execute_move(backend: ElasticBackend, request: PlacementRequest,
                 move: Move, now: float = 0.0,
                 mode: str = MODE_STOP_AND_COPY) -> MigrationPhases:
    """Run one move through the full pipeline synchronously and return its
    per-phase timings — the demo/one-job path (the fleet runtime instead
    drives the same backend through the `MigrationExecutor` event loop,
    which adds fair-share link contention).

    The transfer phase is priced over the slowest link of the move's
    old∪new path (uncontended); snapshot/restore come from the backend
    (live: measured, simulated: derived from the byte count)."""
    snap = backend.snapshot(request, move, now)
    links = {l.link_id: l.bandwidth_mbps for l in move.old.links}
    links.update({l.link_id: l.bandwidth_mbps for l in move.new.links})
    bw = min(links.values(), default=100.0)
    transfer_s = snap.mbits / bw
    restore_s = backend.restore(request, move, snap,
                                now + snap.snapshot_s + transfer_s)
    downtime = pipeline_downtime(mode, snap.snapshot_s, transfer_s, restore_s)
    return MigrationPhases(mode=mode, snapshot_s=snap.snapshot_s,
                           transfer_s=transfer_s, restore_s=restore_s,
                           downtime_s=downtime, mbits=snap.mbits)


def auto_backend(state_mb: float = 64.0) -> ElasticBackend:
    """`LiveElasticBackend` when JAX devices are usable (the demo / real
    deployments), `SimulatedElasticBackend` otherwise (headless sims)."""
    try:
        import jax
        jax.devices()
    except Exception:
        return SimulatedElasticBackend(default_state_mb=state_mb)
    return LiveElasticBackend()
