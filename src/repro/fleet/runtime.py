"""Discrete-event continuous-operation runtime with load-bearing time.

Drives the paper's reconfigurator *over time*: a stream of arrival /
departure / rate-sample / failure events mutates the fleet, and every
``reconfig_every`` admissions (plus after failures and recoveries) the
configured `ReconfigPolicy` trial-solves the recent-apps window — skipping
apps that are mid-migration — weighting each app by its current request
rate.  Accepted plans do NOT complete inside the tick: the
`MigrationExecutor` ledger starts transfers that occupy fractional link
bandwidth over ``[t, t+dur)``, emits `MigrationStart` / `MigrationComplete`
events back into the queue, and holds source-side occupancy until the copy
finishes (the double-booking window).  Every transfer runs the elastic
checkpoint → reshard → resume pipeline through the `fleet.elastic_bridge`
backend seam (`RuntimeConfig.elastic_backend`), so transfer bytes and
snapshot/restore phase times come from checkpoint state, not a flat
constant.  Arrivals, departures, rate swings
and node failures therefore *interleave* with in-flight moves — a flash
crowd can land mid-reconfiguration, and a destination failure aborts and
rolls back the transfers headed there.

The runtime is fully deterministic given its event queue: all randomness
lives in the scenario generators (`fleet.scenarios`), and per-tick
telemetry fingerprints are reproducible (see `fleet.telemetry`) — except
under the `adaptive` policy, whose switching keys off wall-clock solver
latency by design.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.apps import PlacementRequest
from repro.core.placement import PlacementEngine
from repro.core.topology import TIER_INPUT, Topology

from .events import (
    AppArrival,
    AppDeparture,
    DemandDrift,
    Event,
    EventQueue,
    LinkFailure,
    LinkRecovery,
    MigrationComplete,
    MigrationStart,
    NodeFailure,
    NodeRecovery,
    RateBank,
    RateCurve,
    ReconfigTick,
    RequestRateUpdate,
    SessionArrival,
)
from .executor import MigrationExecutor
from .obs.calibration import CalibrationLedger, MovePrediction
from .obs.metrics import (
    DEFAULT_FRACTION_BUCKETS,
    DEFAULT_LATENCY_BUCKETS_S,
    DEFAULT_RATIO_BUCKETS,
    MetricsRegistry,
)
from .obs.slo import SloConfig, SloMonitor
from .obs.trace import NULL_TRACER
from .policies import ReconfigPolicy
from .telemetry import Telemetry, TickRecord


@dataclasses.dataclass
class RuntimeConfig:
    reconfig_every: int = 100      # admissions between scheduled reconfigs
    window: int = 100              # most-recent-N re-placement window
    state_mb: float = 64.0         # migrated state per app
    reconfig_on_failure: bool = True
    check_invariants: bool = True  # occupancy audit after every tick
    rate_epsilon: float = 0.05     # min relative rate change worth re-admitting
    # Planning-window selection: "recent" = the most-recent-N stable apps
    # (the paper's periodic re-placement window); "churn" = only apps whose
    # rate or placement regressed since the last plan (admissions, rate
    # re-admissions, drift, failover) — quiet apps stay out of the window,
    # so the journal/region-reuse machinery bites on busy ticks too.
    window_policy: str = "recent"
    # Bandwidth each active migration debits against admission control on
    # every link it crosses (0 = legacy unreserved transfers).  Since the
    # fair-share ledger refactor this is an on/off knob: each transfer
    # reserves its *live fair-share rate*, re-computed on every contention
    # change, not this flat constant.
    migration_reserve_mbps: float = 2.0
    # Elastic bridge backend executing every migration's checkpoint →
    # reshard → resume pipeline (`fleet.elastic_bridge`).  None → a
    # `SimulatedElasticBackend` whose no-declared-state fallback is the
    # legacy flat `state_mb` model.
    elastic_backend: Optional[object] = None
    # SLO objectives/budgets for the burn-rate monitor (`fleet.obs.slo`).
    # None → the default `SloConfig` (calibrated to stay quiet on healthy
    # runs and burn on sustained degradation).
    slo: Optional[SloConfig] = None
    # Opt-in calibration feedback (`fleet.obs.calibration`): when True and
    # the policy carries a `MigrationCostModel`, the model prices moves
    # with backend-declared byte counts and ledger-measured per-app
    # corrections instead of the flat `state_mb` belief.  Off (default)
    # the cost model's behavior — and every scenario fingerprint — is
    # bit-identical to the pre-calibration code.
    cost_feedback: bool = False
    # Admission path: "vector" = the array-ledger template fast path,
    # "scalar" = the retained per-candidate reference loop.  Both decide
    # identically (property-tested; the benchmark smoke gate asserts
    # bit-identical scenario fingerprints), so this is a perf knob and a
    # parity harness, never a behavior switch.
    admission_mode: str = "vector"
    # Opt-in serving workload (`fleet.serving.ServingConfig`): apps with a
    # serving profile run token-level request streams and migrate with a
    # KV-cache-aware strategy.  None (default) leaves every scenario
    # fingerprint bit-identical to the pre-serving code.
    serving: Optional[object] = None


class FleetRuntime:
    """Event loop over a `PlacementEngine` + policy + migration ledger."""

    def __init__(
        self,
        topo: Topology,
        policy: ReconfigPolicy,
        config: Optional[RuntimeConfig] = None,
        all_sites: bool = False,
        tracer=None,
    ) -> None:
        self.policy = policy
        self.config = config or RuntimeConfig()
        self.engine = PlacementEngine(
            topo, all_sites=all_sites,
            admission_mode=self.config.admission_mode)
        # Serving workload (`fleet.serving`), opt-in: token queues per
        # serving app plus a KV-cache-aware backend (unless the caller
        # supplied one — a `ServingElasticBackend` gets the workload bound,
        # any other backend keeps opaque-blob semantics on purpose).
        self.serving = None
        backend = self.config.elastic_backend
        if self.config.serving is not None:
            from .serving import ServingElasticBackend, ServingWorkload
            self.serving = ServingWorkload(self.config.serving)
            if backend is None:
                backend = ServingElasticBackend(
                    self.serving,
                    default_state_mb=self.config.state_mb,
                    forced_strategy=self.config.serving.forced_strategy)
            elif hasattr(backend, "bind_workload"):
                backend.bind_workload(self.serving)
        self.executor = MigrationExecutor(
            state_mb=self.config.state_mb,
            reserve_mbps=self.config.migration_reserve_mbps,
            backend=backend,
        )
        self.now = 0.0
        self._since_reconfig = 0
        self._events = EventQueue()   # bound to the live queue by run()
        # Request-stream state: per-app curve and the rate its footprint is
        # currently admitted at (1.0 for apps without a curve).  The bank
        # mirrors (curve, admitted rate) in struct-of-arrays form so the
        # periodic resample is one fused numpy pass over the fleet.
        self._curves: Dict[int, RateCurve] = {}
        self._rates: Dict[int, float] = {}
        self._bank = RateBank()
        # Apps whose rate or placement regressed since the last plan —
        # the churn-aware planning window (config.window_policy="churn").
        self._churned: set = set()
        # Observability (`fleet.obs`): the span tracer is strictly additive
        # (behavior-neutral — fingerprints are bit-identical with it on or
        # off); metrics and the SLO monitor are always on and deterministic.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        bind = getattr(policy, "bind_tracer", None)
        if bind is not None:
            bind(self.tracer)
        self.metrics = MetricsRegistry()
        self.slo = SloMonitor(self.config.slo)
        if self.serving is not None:
            self.serving.attach(self.metrics, self.executor)
        # Calibration ledger (`fleet.obs.calibration`): joins plan-time
        # predictions against the executor's measured outcomes.  Always on
        # (deterministic, excluded from fingerprints); feedback into the
        # cost model is the opt-in part.
        self.calibration = CalibrationLedger(
            self.metrics, feedback=self.config.cost_feedback)
        if self.config.cost_feedback:
            cm = getattr(self.policy, "cost_model", None)
            if cm is not None and hasattr(cm, "enable_feedback"):
                cm.enable_feedback(self.executor.backend, self.calibration)
        # Cursor into the executor's append-only migration ledger: records
        # past it are new since the last drain (tracing the executor from
        # outside keeps the reservation ledger observability-free).
        self._rec_cursor = 0

    # ------------------------------------------------------------------ run
    def run(self, events: EventQueue, scenario: str = "", seed: int = 0) -> Telemetry:
        tel = Telemetry(scenario, self.policy.name, seed)
        self._events = events
        while events:
            self.now, ev = events.pop()
            if self.tracer.enabled:
                self.tracer.instant(_event_label(ev), self.now, cat="event")
            self._dispatch(ev, events, tel)
            self._drain_records(tel)
        self._drain_records(tel)
        if self.serving is not None:
            self.serving.finalize(
                self.now, tel,
                mean_ratio=(tel.ticks[-1].mean_satisfaction
                            if tel.ticks else 2.0))
        tel.counters["migrations_dropped"] = self.executor.moves_dropped
        tel.migrations = list(self.executor.records)
        tel.metrics = self.metrics.snapshot()
        tel.calibration = self.calibration.report()
        return tel

    def _dispatch(self, ev: Event, events: EventQueue, tel: Telemetry) -> None:
        c = tel.counters
        if isinstance(ev, AppArrival):
            self._on_arrival(ev, events, tel)
        elif isinstance(ev, AppDeparture):
            # The app may already be gone (failure eviction that found no
            # new home) — departures are idempotent.
            if ev.req_id in self.engine.placed:
                if self.engine.is_migrating(ev.req_id):
                    self.executor.cancel(self.engine, ev.req_id, self.now, events)
                    c["migrations_cancelled"] += 1
                self._forget(ev.req_id)
                self.engine.release(ev.req_id)
                c["departures"] += 1
                self.executor.on_capacity_freed(self.engine, self.now, events)
        elif isinstance(ev, DemandDrift):
            alive = [r for r in self.engine.placement_order
                     if not self.engine.is_migrating(r)]
            if not alive:
                return
            req_id = alive[ev.selector % len(alive)]
            c["drifts"] += 1
            if not self._readmit(req_id, scale=ev.scale):
                c["drift_evicted"] += 1
        elif isinstance(ev, RequestRateUpdate):
            self._on_rate_update(ev, events, tel)
        elif isinstance(ev, MigrationStart):
            c["migrations_started"] += 1
        elif isinstance(ev, MigrationComplete):
            rec = self.executor.on_complete(self.engine, ev.req_id, ev.gen,
                                            self.now, events)
            if rec is not None:
                c["migrations_completed"] += 1
        elif isinstance(ev, NodeFailure):
            self._on_failure(ev, events, tel)
        elif isinstance(ev, NodeRecovery):
            c["recoveries"] += 1
            self.engine.set_node_online(ev.node_id, True)
            self.executor.on_capacity_freed(self.engine, self.now, events)
            if self.config.reconfig_on_failure:
                self._tick("recovery", tel, events)
        elif isinstance(ev, LinkFailure):
            self._on_link_failure(ev, events, tel)
        elif isinstance(ev, LinkRecovery):
            c["link_recoveries"] += 1
            self.engine.set_link_online(ev.link_id, True)
            self.executor.on_capacity_freed(self.engine, self.now, events)
            if self.config.reconfig_on_failure:
                self._tick("recovery", tel, events)
        elif isinstance(ev, SessionArrival):
            if self.serving is None:
                raise TypeError(
                    "SessionArrival requires RuntimeConfig.serving")
            self.serving.on_session(ev.req_id, ev.session_id,
                                    ev.prompt_tokens, ev.decode_tokens,
                                    self.now, self._rates.get(ev.req_id, 1.0))
        elif isinstance(ev, ReconfigTick):
            self._tick("tick", tel, events)
        else:
            raise TypeError(f"unknown event {ev!r}")

    # --------------------------------------------------------------- events
    def _on_arrival(self, ev: AppArrival, events: EventQueue, tel: Telemetry) -> None:
        c = tel.counters
        c["arrivals"] += 1
        inflight = self.executor.n_inflight > 0
        if inflight:
            c["arrivals_inflight"] += 1
        req = ev.request
        rate0 = 1.0
        if ev.rate_curve is not None:
            rate0 = ev.rate_curve.rate(self.now)
            req = _scaled_request(req, rate0)
        t0 = time.perf_counter()
        placed = self.engine.place(req)
        # Wall-clock admission latency (excluded from fingerprints, like
        # every `admission/` metric — see telemetry.WALL_CLOCK_METRIC_PREFIXES).
        self.metrics.histogram("admission/place_s",
                               DEFAULT_LATENCY_BUCKETS_S).observe(
            time.perf_counter() - t0)
        if placed is None:
            c["rejected"] += 1
            if inflight:
                c["rejected_inflight"] += 1
            return
        c["admitted"] += 1
        if self.serving is not None:
            self.serving.register(req.req_id, self.now)
        if ev.rate_curve is not None:
            self._curves[req.req_id] = ev.rate_curve
            self._bank.add(req.req_id, ev.rate_curve, rate0)
        self._rates[req.req_id] = rate0
        self._churned.add(req.req_id)
        if ev.lifetime_s is not None:
            events.push(self.now + ev.lifetime_s, AppDeparture(req.req_id))
        self._since_reconfig += 1
        if self._since_reconfig >= self.config.reconfig_every:
            self._tick("arrivals", tel, events)

    def _on_rate_update(self, ev: RequestRateUpdate, events: EventQueue,
                        tel: Telemetry) -> None:
        c = tel.counters
        # One fused numpy pass over every curve (RateBank) replaces the
        # per-app Python loop; the re-admissions then run in the exact
        # placement order the loop used, preserving its semantics
        # (mid-migration apps skipped, rates confirmed only on success).
        changed = self._bank.sample(self.now, self.config.rate_epsilon)
        if changed:
            # Consume the batch: only the changed apps, in the exact
            # admission order the historical placement_order scan visited
            # them (engine.in_admission_order), instead of probing every
            # placed app per rate event.
            for req_id in self.engine.in_admission_order(changed):
                if self.engine.is_migrating(req_id):
                    continue
                target = changed[req_id]
                cur = self._rates.get(req_id, 1.0)
                c["rate_updates"] += 1
                if self._readmit(req_id, scale=target / cur):
                    self._rates[req_id] = target
                    self._bank.set_rate(req_id, target)
                else:
                    c["rate_evicted"] += 1
        if self.now + ev.every_s <= ev.horizon_s:
            events.push(self.now + ev.every_s, ev)

    def _on_failure(self, ev: NodeFailure, events: EventQueue, tel: Telemetry) -> None:
        c = tel.counters
        c["failures"] += 1
        self.engine.set_node_online(ev.node_id, False)
        # First let the ledger abort transfers touching the dead node …
        rolled_back, homeless = self.executor.on_node_failure(
            self.engine, ev.node_id, self.now, events)
        c["migrations_aborted"] += len(rolled_back) + len(homeless)
        c["migration_rollbacks"] += len(rolled_back)
        for req_id in homeless:
            # Suspended app whose destination died: its source slot is gone
            # too, so re-place it anywhere (or lose it).
            if self._readmit(req_id):
                c["failover_moved"] += 1
            else:
                c["migration_lost"] += 1
        # … then evict the apps whose live copy sat on the node.
        for req_id in self.engine.apps_on_node(ev.node_id):
            if self._readmit(req_id):
                c["failover_moved"] += 1
            else:
                c["failover_lost"] += 1
        if self.config.reconfig_on_failure:
            self._tick("failure", tel, events)

    def _on_link_failure(self, ev: LinkFailure, events: EventQueue,
                         tel: Telemetry) -> None:
        """Uplink/backbone cut: candidate paths through the link become
        infeasible, transfers crossing it are aborted with source rollback
        (`executor.on_link_failure`), then every app whose live path used
        the link is evicted and re-placed (or lost)."""
        c = tel.counters
        c["link_failures"] += 1
        self.engine.set_link_online(ev.link_id, False)
        rolled_back, homeless = self.executor.on_link_failure(
            self.engine, ev.link_id, self.now, events)
        c["migrations_aborted"] += len(rolled_back) + len(homeless)
        c["migration_rollbacks"] += len(rolled_back)
        for req_id in homeless:
            if self._readmit(req_id):
                c["linkfail_moved"] += 1
            else:
                c["migration_lost"] += 1
        for req_id in self.engine.apps_on_link(ev.link_id):
            if self._readmit(req_id):
                c["linkfail_moved"] += 1
            else:
                c["linkfail_lost"] += 1
        if self.config.reconfig_on_failure:
            self._tick("failure", tel, events)

    # -------------------------------------------------------------- helpers
    def _forget(self, req_id: int) -> None:
        self._curves.pop(req_id, None)
        self._rates.pop(req_id, None)
        self._bank.discard(req_id)
        self._churned.discard(req_id)
        if self.serving is not None:
            # Departure or lost to a failure: serve what completed by now,
            # cancel the rest (the conservation ledger's `cancelled` side).
            self.serving.on_departure(req_id, self.now)

    def _readmit(self, req_id: int, scale: float = 1.0) -> bool:
        """Release ``req_id`` and place it again (rescaling its bandwidth/
        data footprint).  Returns False if no home was found — the app is
        lost (recorded in ``engine.rejected``).  Never called on a
        mid-migration app: the runtime cancels/aborts its transfer first."""
        placed = self.engine.placed[req_id]
        req = placed.request
        if scale != 1.0:
            req = _scaled_request(req, scale)
        t0 = time.perf_counter()
        self.engine.release(req_id)
        ok = self.engine.place(req) is not None
        self.metrics.histogram("admission/readmit_s",
                               DEFAULT_LATENCY_BUCKETS_S).observe(
            time.perf_counter() - t0)
        if not ok:
            self._forget(req_id)
        else:
            # Every re-admission path (rate swing, drift, failover, link
            # cut) is a rate or placement regression — churn.  Migration
            # completions are planned improvements and do NOT mark.
            self._churned.add(req_id)
        self.executor.on_capacity_freed(self.engine, self.now, self._events)
        return ok

    def _utilization(self) -> tuple:
        """(aggregate, max) used/capacity over online nodes of the device
        kinds the current population actually consumes."""
        kinds = {a.request.app.device_kind for a in self.engine.placed.values()}
        used = cap = 0.0
        worst = 0.0
        for nid, node in self.engine.topo.nodes.items():
            if nid in self.engine.offline_nodes or node.kind not in kinds:
                continue
            if self.engine.topo.sites[node.site_id].tier == TIER_INPUT:
                continue
            used += self.engine.node_used[nid]
            cap += node.capacity
            worst = max(worst, self.engine.node_used[nid] / node.capacity)
        return (used / cap if cap else 0.0), worst

    def _mean_rate(self) -> float:
        if not self.engine.placed:
            return 0.0
        return sum(self._rates.get(r, 1.0) for r in self.engine.placed) / len(
            self.engine.placed)

    def _select_window(self) -> list:
        """The re-placement window for this tick.  ``recent``: the paper's
        most-recent-N stable apps.  ``churn``: only apps marked churned
        since the last plan (still capped at N, most recent first), so a
        busy tick re-plans exactly what regressed."""
        if self.config.window_policy == "churn":
            eng = self.engine
            churned = self._churned
            window = [r for r in eng.placement_order
                      if r in churned and not eng.is_migrating(r)]
            return window[-self.config.window:]
        return self.engine.recent_stable(self.config.window)

    def _tick(self, trigger: str, tel: Telemetry, events: EventQueue) -> None:
        self._since_reconfig = 0
        window = self._select_window()
        if not window:
            return
        with self.tracer.span("tick", cat="tick",
                              args={"trigger": trigger, "t_sim": self.now,
                                    "window": len(window)}):
            self._tick_body(trigger, tel, events, window)
        # Planned: these apps got their re-placement look; drop them from
        # the churn set so the next window is the next delta.
        if self.config.window_policy == "churn":
            self._churned.difference_update(window)

    def _tick_body(self, trigger: str, tel: Telemetry, events: EventQueue,
                   window) -> None:
        if self.serving is not None:
            # Bring every token queue current *before* planning so the
            # strategy pricing (cached context, decode backlog) sees the
            # fleet as of this tick, then flush the latency histograms.
            self.serving.observe_tick(self.now)
        weights = {r: self._rates.get(r, 1.0) for r in window}
        observe = getattr(self.policy, "observe", None)
        if observe is not None:
            # Context the planner subsystem consumes: the simulated clock
            # and rate curves (rolling-horizon forecasts) and the executor
            # ledger (migration-aware move pricing).
            observe(now=self.now, curves=self._curves, executor=self.executor)
        # The "plan" span wraps the whole policy call; the planner emits its
        # own child spans (journal_scan / region_solve / arbitration).
        with self.tracer.span("plan", cat="tick"):
            res = self.policy.plan(self.engine, window, weights=weights)
        stats = getattr(self.policy, "last_plan_stats", None)
        n_started = 0
        with self.tracer.span("commit", cat="tick",
                              args={"accepted": res.accepted,
                                    "moves": len(res.moves)}):
            if res.accepted and res.moves:
                n_started = self.executor.begin(self.engine, res, self.now,
                                                events)
                tel.counters["moves"] += res.n_moved
                self._record_predictions(res)
        util, util_max = self._utilization()
        # Post-tick fleet satisfaction (weighted mean X+Y over the window):
        # the planned value when the plan was accepted, else the do-nothing
        # baseline 2.0 — simulated, deterministic, and the SLO input.
        mean_sat = res.s_after / len(window) if res.accepted else 2.0
        rec = TickRecord(
            t=self.now,
            trigger=trigger,
            n_alive=len(self.engine.placed),
            window=len(window),
            n_moved=res.n_moved if res.accepted else 0,
            accepted=res.accepted,
            gain=res.gain if res.accepted else 0.0,
            mean_moved_ratio=res.mean_moved_ratio if res.accepted else None,
            mean_moved_ratio_weighted=(res.mean_moved_ratio_weighted
                                       if res.accepted else None),
            mean_rate=self._mean_rate(),
            solver_time_s=res.plan_time_s,
            n_started=n_started,
            n_inflight=self.executor.n_inflight,
            utilization=util,
            utilization_max=util_max,
            n_regions=stats.n_regions if stats else 0,
            boundary_crossings=stats.boundary_crossings if stats else 0,
            region_solve_max_s=stats.region_solve_max_s if stats else 0.0,
            forecast_error=stats.forecast_error if stats else None,
            regions_reused=stats.regions_reused if stats else 0,
            warm_start_hits=stats.warm_start_hits if stats else 0,
            n_feasible=stats.n_feasible if stats else 0,
            subtrees_skipped=stats.subtrees_skipped if stats else 0,
            mean_satisfaction=mean_sat,
            build_s=stats.build_s if stats else 0.0,
            lp_iterations=stats.lp_iterations if stats else 0,
            bnb_nodes=stats.bnb_nodes if stats else 0,
        )
        tel.ticks.append(rec)
        self._observe_tick_metrics(rec, stats)
        for breach in self.slo.observe_tick(self.now, mean_sat):
            self._on_breach(breach, tel)
        if self.config.check_invariants and not self.engine.occupancy_invariants_ok():
            raise AssertionError("occupancy invariants violated after tick")

    # -------------------------------------------------------- observability
    def _record_predictions(self, res) -> None:
        """Capture the plan's quantified beliefs about each committed move
        — wire size, phase times, fair-share rate, satisfaction gain — in
        the calibration ledger, to be joined against the executor's
        measured `MigrationRecord` when the transfer resolves.

        The prediction mirrors what the *planner* believed, not what the
        executor knows: with ``cost_feedback`` off that is the flat
        ``state_mb`` copy with zero host phases (the legacy pricing
        belief); with feedback on it is the backend's declared phases,
        overridden by ledger-measured per-app values once available."""
        shares = self.executor.link_shares()
        for mv in res.moves:
            placed = self.engine.placed.get(mv.req_id)
            if placed is None:
                continue
            links = {l.link_id: l.bandwidth_mbps for l in mv.old.links}
            links.update({l.link_id: l.bandwidth_mbps for l in mv.new.links})
            uncont = min(links.values(), default=100.0)
            rate = min((bw / max(shares.get(lid, 1), 1)
                        for lid, bw in links.items()), default=100.0)
            if self.config.cost_feedback:
                mbits, snap_s, rest_s = self.executor.backend.predict_phases(
                    placed.request, mv)
                learned = self.calibration.learned_mbits(mv.req_id)
                if learned is not None:
                    mbits = learned
                host = self.calibration.learned_host(mv.req_id)
                if host is not None:
                    snap_s, rest_s = host
            else:
                mbits = self.executor.state_mb * 8.0
                snap_s = rest_s = 0.0
            # Serving apps: the prediction carries the strategy the backend
            # would choose for this move *now*, and its per-strategy phases
            # (the executor re-chooses at transfer start — the record's
            # strategy is the measured truth the join scores against).
            strategy = None
            backend = self.executor.backend
            if self.serving is not None and hasattr(backend,
                                                    "strategy_phases"):
                phases = backend.strategy_phases(placed.request, mv)
                if phases is not None:
                    strategy = backend.choose_strategy(placed.request, mv)
                    mbits, snap_s, rest_s = phases[strategy]
            prov = (res.provenance or {}).get(mv.req_id)
            if strategy is not None and prov is not None:
                prov = dataclasses.replace(prov, strategy=strategy)
            self.calibration.record_move(MovePrediction(
                req_id=mv.req_id,
                t_plan=self.now,
                mbits=mbits,
                snapshot_s=snap_s,
                transfer_s=mbits / max(rate, 1e-9),
                restore_s=rest_s,
                rate_mbps=rate,
                uncontended_mbps=uncont,
                gain=2.0 - mv.ratio,
                r_before=mv.old.response_s,
                p_before=mv.old.price,
                feedback=self.config.cost_feedback,
                provenance=prov,
                strategy=strategy,
            ))

    def _observe_tick_metrics(self, rec: TickRecord, stats) -> None:
        m = self.metrics
        m.counter("tick/count").inc()
        m.counter("tick/accepted").inc(int(rec.accepted))
        m.histogram("tick/satisfaction",
                    DEFAULT_RATIO_BUCKETS).observe(rec.mean_satisfaction)
        m.histogram("tick/moved_ratio",
                    DEFAULT_FRACTION_BUCKETS).observe(rec.moved_ratio)
        m.histogram("node/utilization",
                    DEFAULT_FRACTION_BUCKETS).observe(rec.utilization)
        m.histogram("solver/latency_s",
                    DEFAULT_LATENCY_BUCKETS_S).observe(rec.solver_time_s)
        # Per-link utilization (reservations included) + contention: links
        # running above 90% of their bandwidth this tick.
        link_hist = m.histogram("link/utilization", DEFAULT_FRACTION_BUCKETS)
        contended = 0
        # One array pass over the link ledger (identical values to the
        # per-link `link_remaining` sweep: same IEEE op order); the
        # observe() loop stays sequential in topology link order so the
        # histogram stream — and thus the tick fingerprint — is unchanged.
        caps, rem = self.engine.link_capacity_remaining()
        with np.errstate(divide="ignore", invalid="ignore"):
            utils = 1.0 - rem / caps
        for cap, u in zip(caps.tolist(), utils.tolist()):
            if cap <= 0.0:
                continue
            link_hist.observe(u)
            if u > 0.9:
                contended += 1
        m.counter("link/contended").inc(contended)
        if stats is not None:
            m.counter("planner/regions_solved").inc(stats.n_regions)
            m.counter("planner/regions_reused").inc(stats.regions_reused)
            m.counter("planner/subtrees_skipped").inc(stats.subtrees_skipped)
            m.counter("planner/warm_start_hits").inc(stats.warm_start_hits)
            m.counter("planner/warm_start_misses").inc(stats.warm_start_misses)
            m.counter("solver/lp_iterations").inc(stats.lp_iterations)
            m.counter("solver/bnb_nodes").inc(stats.bnb_nodes)
            m.histogram("planner/build_s",
                        DEFAULT_LATENCY_BUCKETS_S).observe(stats.build_s)
        if rec.forecast_error is not None:
            fc = getattr(self.policy, "forecaster", None)
            self.calibration.observe_forecast(
                rec.t, rec.forecast_error,
                getattr(fc, "last_residuals", None) if fc is not None else None)

    def _drain_records(self, tel: Telemetry) -> None:
        """Consume executor ledger rows appended since the last drain:
        migration metrics + sim-time trace spans + the downtime SLO.  The
        phases of one transfer are sequential (snapshot → copy → restore),
        so their sim-time intervals reconstruct exactly from the record."""
        records = self.executor.records
        while self._rec_cursor < len(records):
            i = self._rec_cursor
            rec = records[i]
            self._rec_cursor += 1
            m = self.metrics
            m.counter(f"migration/{rec.outcome}").inc()
            m.histogram("migration/downtime_s",
                        DEFAULT_LATENCY_BUCKETS_S).observe(rec.downtime_s)
            if rec.outcome == "completed":
                m.histogram("migration/duration_s",
                            DEFAULT_LATENCY_BUCKETS_S).observe(rec.duration_s)
            # Predicted-vs-actual join: the executor's measurement
            # side-channel is index-aligned with its record ledger.
            meas = (self.executor.measurements[i]
                    if i < len(self.executor.measurements) else None)
            pred, _ = self.calibration.observe_record(rec, meas)
            if self.serving is not None:
                self.serving.on_record(rec)
            if pred is not None and rec.outcome == "completed":
                placed = self.engine.placed.get(rec.req_id)
                if placed is not None:
                    realized = 2.0 - (
                        placed.response_s / max(pred.r_before, 1e-9)
                        + placed.price / max(pred.p_before, 1e-9))
                    self.calibration.observe_gain(rec.t_end, pred.gain,
                                                  realized)
            if self.tracer.enabled:
                track = f"mig {i}: app {rec.req_id}"
                snap_end = min(rec.t_start + rec.snapshot_s, rec.t_end)
                restore_start = max(rec.t_end - rec.restore_s, snap_end)
                span_args = {"mode": rec.mode, "outcome": rec.outcome,
                             "downtime_s": rec.downtime_s}
                if rec.strategy is not None:
                    span_args["strategy"] = rec.strategy
                if pred is not None and pred.provenance is not None:
                    span_args["why"] = pred.provenance.to_dict()
                self.tracer.add_span(
                    f"migrate #{rec.req_id}", "migration", track,
                    rec.t_start, rec.t_end, args=span_args)
                self.tracer.add_span("snapshot", "migration", track,
                                     rec.t_start, snap_end)
                self.tracer.add_span("copy", "migration", track,
                                     snap_end, restore_start)
                self.tracer.add_span("restore", "migration", track,
                                     restore_start, rec.t_end)
            for breach in self.slo.observe_migration(rec.t_end,
                                                     rec.downtime_s):
                self._on_breach(breach, tel)

    def _on_breach(self, breach, tel: Telemetry) -> None:
        """Record an SLO breach and forward it to the policy's
        ``on_slo_breach`` hook (observe → act: `AdaptivePolicy` escalates
        one tier toward the exact solver)."""
        tel.slo_breaches.append(breach)
        tel.counters["slo_breaches"] += 1
        self.metrics.counter(f"slo/{breach.slo}_breaches").inc()
        if self.tracer.enabled:
            self.tracer.instant(f"SloBreach:{breach.slo}", breach.t,
                                cat="slo",
                                args={"burn_rate": round(breach.burn_rate, 3)})
        hook = getattr(self.policy, "on_slo_breach", None)
        if hook is not None and hook(breach):
            tel.counters["slo_escalations"] += 1
            self.metrics.counter("slo/escalations").inc()


def _event_label(ev: Event) -> str:
    """Trace-instant label for a fleet event (req/node/link id when the
    event carries one)."""
    name = type(ev).__name__
    for attr in ("req_id", "node_id", "link_id"):
        v = getattr(ev, attr, None)
        if v is not None:
            return f"{name} {v}"
    req = getattr(ev, "request", None)
    if req is not None:
        return f"{name} {req.req_id}"
    return name


def _scaled_request(req: PlacementRequest, scale: float) -> PlacementRequest:
    app = dataclasses.replace(
        req.app,
        bandwidth_mbps=req.app.bandwidth_mbps * scale,
        data_mb=req.app.data_mb * scale,
    )
    return PlacementRequest(req.req_id, app, req.input_site, req.requirement)
