"""Discrete-event continuous-operation runtime.

Drives the paper's reconfigurator *over time* instead of once: a stream of
arrival / departure / drift / failure events mutates the fleet, and every
``reconfig_every`` admissions (plus after failures and recoveries) the
configured `ReconfigPolicy` trial-solves the recent-apps window; accepted
plans are executed through the bandwidth-aware `MigrationExecutor`.

The runtime is fully deterministic given its event queue: all randomness
lives in the scenario generators (`fleet.scenarios`), and per-tick telemetry
fingerprints are reproducible (see `fleet.telemetry`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.apps import PlacementRequest
from repro.core.placement import PlacementEngine
from repro.core.topology import TIER_INPUT, Topology

from .events import (
    AppArrival,
    AppDeparture,
    DemandDrift,
    Event,
    EventQueue,
    NodeFailure,
    NodeRecovery,
    ReconfigTick,
)
from .executor import MigrationExecutor, MigrationSchedule
from .policies import ReconfigPolicy
from .telemetry import Telemetry, TickRecord


@dataclasses.dataclass
class RuntimeConfig:
    reconfig_every: int = 100      # admissions between scheduled reconfigs
    window: int = 100              # most-recent-N re-placement window
    state_mb: float = 64.0         # migrated state per app
    reconfig_on_failure: bool = True
    check_invariants: bool = True  # occupancy audit after every tick


class FleetRuntime:
    """Event loop over a `PlacementEngine` + policy + migration executor."""

    def __init__(
        self,
        topo: Topology,
        policy: ReconfigPolicy,
        config: Optional[RuntimeConfig] = None,
        all_sites: bool = False,
    ) -> None:
        self.engine = PlacementEngine(topo, all_sites=all_sites)
        self.policy = policy
        self.config = config or RuntimeConfig()
        self.executor = MigrationExecutor(state_mb=self.config.state_mb)
        self.now = 0.0
        self._since_reconfig = 0

    # ------------------------------------------------------------------ run
    def run(self, events: EventQueue, scenario: str = "", seed: int = 0) -> Telemetry:
        tel = Telemetry(scenario, self.policy.name, seed)
        while events:
            self.now, ev = events.pop()
            self._dispatch(ev, events, tel)
        return tel

    def _dispatch(self, ev: Event, events: EventQueue, tel: Telemetry) -> None:
        c = tel.counters
        if isinstance(ev, AppArrival):
            c["arrivals"] += 1
            placed = self.engine.place(ev.request)
            if placed is None:
                c["rejected"] += 1
                return
            c["admitted"] += 1
            if ev.lifetime_s is not None:
                events.push(self.now + ev.lifetime_s, AppDeparture(ev.request.req_id))
            self._since_reconfig += 1
            if self._since_reconfig >= self.config.reconfig_every:
                self._tick("arrivals", tel)
        elif isinstance(ev, AppDeparture):
            # The app may already be gone (failure eviction that found no
            # new home) — departures are idempotent.
            if ev.req_id in self.engine.placed:
                self.engine.release(ev.req_id)
                c["departures"] += 1
        elif isinstance(ev, DemandDrift):
            alive = self.engine.placement_order
            if not alive:
                return
            req_id = alive[ev.selector % len(alive)]
            c["drifts"] += 1
            if not self._readmit(req_id, scale=ev.scale):
                c["drift_evicted"] += 1
        elif isinstance(ev, NodeFailure):
            c["failures"] += 1
            self.engine.set_node_online(ev.node_id, False)
            for req_id in self.engine.apps_on_node(ev.node_id):
                if self._readmit(req_id):
                    c["failover_moved"] += 1
                else:
                    c["failover_lost"] += 1
            if self.config.reconfig_on_failure:
                self._tick("failure", tel)
        elif isinstance(ev, NodeRecovery):
            c["recoveries"] += 1
            self.engine.set_node_online(ev.node_id, True)
            if self.config.reconfig_on_failure:
                self._tick("recovery", tel)
        elif isinstance(ev, ReconfigTick):
            self._tick("tick", tel)
        else:
            raise TypeError(f"unknown event {ev!r}")

    # -------------------------------------------------------------- helpers
    def _readmit(self, req_id: int, scale: float = 1.0) -> bool:
        """Release ``req_id`` and place it again (drift rescaling its
        bandwidth/data footprint).  Returns False if no home was found —
        the app is lost (recorded in ``engine.rejected``)."""
        placed = self.engine.placed[req_id]
        req = placed.request
        if scale != 1.0:
            app = dataclasses.replace(
                req.app,
                bandwidth_mbps=req.app.bandwidth_mbps * scale,
                data_mb=req.app.data_mb * scale,
            )
            req = PlacementRequest(req.req_id, app, req.input_site, req.requirement)
        self.engine.release(req_id)
        return self.engine.place(req) is not None

    def _utilization(self) -> tuple:
        """(aggregate, max) used/capacity over online nodes of the device
        kinds the current population actually consumes."""
        kinds = {a.request.app.device_kind for a in self.engine.placed.values()}
        used = cap = 0.0
        worst = 0.0
        for nid, node in self.engine.topo.nodes.items():
            if nid in self.engine.offline_nodes or node.kind not in kinds:
                continue
            if self.engine.topo.sites[node.site_id].tier == TIER_INPUT:
                continue
            used += self.engine.node_used[nid]
            cap += node.capacity
            worst = max(worst, self.engine.node_used[nid] / node.capacity)
        return (used / cap if cap else 0.0), worst

    def _tick(self, trigger: str, tel: Telemetry) -> None:
        self._since_reconfig = 0
        window = self.engine.recent(min(self.config.window,
                                        len(self.engine.placement_order)))
        if not window:
            return
        res = self.policy.plan(self.engine, window)
        schedule = MigrationSchedule([], self.config.state_mb)
        if res.accepted and res.moves:
            schedule = self.executor.execute(self.engine, res)
            tel.counters["moves"] += res.n_moved
        util, util_max = self._utilization()
        tel.ticks.append(TickRecord(
            t=self.now,
            trigger=trigger,
            n_alive=len(self.engine.placed),
            window=len(window),
            n_moved=res.n_moved if res.accepted else 0,
            accepted=res.accepted,
            gain=res.gain if res.accepted else 0.0,
            mean_moved_ratio=res.mean_moved_ratio if res.accepted else 2.0,
            solver_time_s=res.plan_time_s,
            migration_makespan_s=schedule.makespan_s,
            migration_overlap=schedule.overlap_factor,
            total_downtime_s=schedule.total_downtime_s,
            utilization=util,
            utilization_max=util_max,
        ))
        if self.config.check_invariants and not self.engine.occupancy_invariants_ok():
            raise AssertionError("occupancy invariants violated after tick")
