"""Data pipeline: deterministic synthetic + byte-text sources, prefetch."""
from .pipeline import (  # noqa: F401
    ByteTokenizer, DataConfig, Prefetcher, SyntheticLM, TextFileLM,
)
