"""Deterministic, shardable data pipeline.

For a multi-host fleet each process loads only its batch shard
(``process_index``-strided), with background prefetch.  Sources: a seeded
synthetic LM stream (benchmarks / dry-runs / tests — fully deterministic and
restart-consistent via the step-indexed PRNG) and a byte-tokenized text file
source for the example drivers.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Step-indexed synthetic stream: batch(step) is a pure function of
    (seed, step, host), so a restarted trainer resumes on identical data —
    the property the checkpoint/restart tests rely on."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        toks = rng.integers(
            0, cfg.vocab_size, size=(cfg.host_batch, cfg.seq_len + 1), dtype=np.int64)
        # Plant n-gram structure so loss can actually fall in examples.
        toks[:, 2::3] = (toks[:, 1::3][:, : toks[:, 2::3].shape[1]]
                         * 31 + 7) % cfg.vocab_size
        return {"inputs": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteTokenizer:
    """Trivial byte-level tokenizer (vocab 256 + bos/eos)."""

    vocab_size = 258
    bos = 256
    eos = 257

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        b = bytes(int(i) for i in ids if int(i) < 256)
        return b.decode("utf-8", errors="replace")


class TextFileLM:
    """Chunk a byte-tokenized file into (inputs, targets) windows."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tok = ByteTokenizer()
        with open(path, "rb") as f:
            data = np.frombuffer(f.read(), dtype=np.uint8).astype(np.int32)
        self.data = data

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        starts = rng.integers(0, len(self.data) - cfg.seq_len - 1, size=cfg.host_batch)
        rows = np.stack([self.data[s:s + cfg.seq_len + 1] for s in starts])
        return {"inputs": rows[:, :-1], "targets": rows[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded buffer."""

    def __init__(self, source, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._src = iter(source)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._src:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
