"""repro: environment-adaptive deployment reconfiguration (Yamato 2022) as a
first-class scheduler layer of a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
