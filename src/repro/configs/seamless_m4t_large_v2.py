"""seamless-m4t-large-v2 — encoder-decoder backbone (arXiv:2308.11596).
24 encoder + 24 decoder layers, d_model 1024, 16 heads, d_ff 8192,
vocab 256206.  The speech/text frontend is a STUB: `input_specs()` feeds
precomputed frame embeddings (B, S_enc, d) to the encoder."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    ffn_type="gelu",
    frontend_stub=True,
)
