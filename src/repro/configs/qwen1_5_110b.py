"""qwen1.5-110b — dense GQA decoder with QKV bias (Qwen1.5 family trait).
[hf:Qwen/Qwen1.5-110B]: 80L, d_model 8192, 64 heads (kv 8), d_ff 49152,
vocab 152064.  Uses Adafactor-class optimizer states at this size so the
1-pod dry-run fits HBM (see DESIGN.md)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    ffn_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
)
