"""Assigned architecture registry: ``get_config("<arch-id>")``.

Every entry reproduces the published configuration named in the assignment
table; see each module's docstring for the source and any interpretation
notes (recorded per DESIGN.md §5).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-3-2b": "granite_3_2b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "xlstm-1.3b": "xlstm_1_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
