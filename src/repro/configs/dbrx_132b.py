"""dbrx-132b — fine-grained MoE (hf:databricks/dbrx-base).
40L, d_model 6144, 48 heads (kv 8), 16 experts top-4, expert d_ff 10752,
vocab 100352."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    ffn_type="swiglu",
    n_experts=16,
    top_k=4,
    rope_theta=500_000.0,
    optimizer="adafactor",
)
