"""xlstm-1.3b — xLSTM stack, [7:1] mLSTM:sLSTM ratio (Beck et al. 2024,
arXiv:2405.04517): 48 blocks, d_model 2048, 4 heads, vocab 50304, d_ff 0
(the mixers carry their own up/down projections, proj_factor 2).
Interpretation note: the assignment's "(GQA kv=4)" denotes the 4-head
recurrent structure; xLSTM has no KV cache — state is O(1)."""

from repro.models.config import (
    BLOCK_MLSTM,
    BLOCK_SLSTM,
    ModelConfig,
)

_PATTERN = tuple(
    BLOCK_SLSTM if (i % 8 == 7) else BLOCK_MLSTM for i in range(48)
)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_head=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
    ssm_expand=2,
    tie_embeddings=True,
)
