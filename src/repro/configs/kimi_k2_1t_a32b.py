"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).
61L, d_model 7168, 64 heads (kv 8), 384 experts top-8, expert d_ff 2048,
vocab 163840.  Interpretation: the assignment's d_ff=2048 is the per-expert
hidden (Kimi-K2's moe_intermediate_size); all layers are MoE here (the real
model's single dense first layer is a <0.1 % param deviation, noted in
DESIGN.md).  Adafactor states + full 2-axis sharding are required to fit a
1-pod v5e (16 GB HBM) — see EXPERIMENTS.md §Dry-run."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    ffn_type="swiglu",
    n_experts=384,
    top_k=8,
    rope_theta=50_000.0,
    optimizer="adafactor",
)
