"""nemotron-4-15b — dense GQA decoder with squared-ReLU MLP.
[arXiv:2402.16819]: 32L, d_model 6144, 48 heads (kv 8), d_ff 24576,
vocab 256000.  Nemotron-4 uses squared-ReLU (no gating) and RoPE."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    ffn_type="relu2",
    rope_theta=10_000.0,
)
