"""qwen1.5-0.5b — small dense decoder, QKV bias, MHA (kv == heads).
[hf:Qwen/Qwen1.5-0.5B]: 24L, d_model 1024, 16 heads (kv 16), d_ff 2816,
vocab 151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    ffn_type="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
