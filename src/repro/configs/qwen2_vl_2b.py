"""qwen2-vl-2b — VLM decoder backbone with M-RoPE (arXiv:2409.12191).
28L, d_model 1536, 12 heads (kv 2), d_ff 8960, vocab 151936.  The dynamic-
resolution ViT frontend is a STUB: `input_specs()` provides patch embeddings
(B, P, d) + 3D (t,h,w) position ids; M-RoPE sections (16,24,24) over
d_head/2 = 64 follow the released config."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    ffn_type="swiglu",
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    vision_stub_patches=256,
)
