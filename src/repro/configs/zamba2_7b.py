"""zamba2-7b — hybrid Mamba2 stack with a weight-SHARED attention block
(arXiv:2411.15242).  81 Mamba2 layers (d_model 3584, state 64) with the
shared full-attention+MLP block applied every 6 layers; 32 heads (kv=32 ⇒
MHA) and d_ff 14336 for the shared block."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    mamba_headdim=64,
    shared_attn_every=6,
    ffn_type="gelu",
)
