"""Base layers: norms, embeddings, RoPE/M-RoPE, activations, linear init.

Pure-functional: ``init_*`` builds param pytrees (plain dicts of jnp arrays);
``apply`` logic is free functions.  Naming conventions of leaves matter —
`repro.parallel.sharding` maps leaf paths to PartitionSpecs by name.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def dtype_of(name: str):
    return jnp.dtype(name)


@jax.custom_vjp
def _bf16_barrier_core(x):
    return x


def _bf16_bar_fwd(x):
    return x, None


def _bf16_bar_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_bf16_barrier_core.defvjp(_bf16_bar_fwd, _bf16_bar_bwd)


def bf16_cotangent_barrier(x):
    """Identity whose backward casts the cotangent to bf16 — placed on the
    residual stream it stops fp32 gradient chains (born in fp32 softmax/norm
    internals) from propagating through every dot transpose and activation
    psum (§Perf: halves backward HBM+wire traffic).  No-op for non-bf16
    primals (fp32 smoke configs)."""
    return _bf16_barrier_core(x) if x.dtype == jnp.bfloat16 else x


# ------------------------------------------------------------------ linear
def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-ish), matching common LM practice."""
    if scale is None:
        scale = d_in ** -0.5
    w = (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(p, x, compute_dtype):
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# -------------------------------------------------------------------- norm
def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, scale, eps: float):
    with jax.named_scope("kscope_rmsnorm"):
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps)
        return (out * scale.astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int, dtype):
    return {"embedding": (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)}


def embed(p, tokens, compute_dtype):
    return jnp.take(p["embedding"], tokens, axis=0).astype(compute_dtype)


def unembed(p, x, logit_dtype):
    return jnp.einsum("...d,vd->...v", x, p["embedding"]).astype(logit_dtype)


# ------------------------------------------------------------- activations
def relu2(x):
    """Squared ReLU (Nemotron-4 / Primer)."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {"gelu": jax.nn.gelu, "relu2": relu2, "silu": jax.nn.silu}


# -------------------------------------------------------------------- RoPE
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions_thw: jnp.ndarray,  # (3, ..., S) — temporal / height / width ids
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the Dh/2 frequency slots are partitioned into
    (temporal, height, width) sections, each rotated by its own position id.
    Text tokens use identical t/h/w ids, recovering standard RoPE."""
    d_head = x.shape[-1]
    if sum(sections) != d_head // 2:
        raise ValueError(f"mrope sections {sections} must sum to d_head/2={d_head // 2}")
    freqs = rope_freqs(d_head, theta)                 # (Dh/2,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d_head // 2)
    # Select, per frequency slot, the position id of its section.
    pos = jnp.take(jnp.moveaxis(positions_thw, 0, -1), sec_id, axis=-1)  # (..., S, Dh/2)
    angles = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_tables(cfg: ModelConfig, positions: jnp.ndarray):
    """Precompute (cos, sin) rotation tables ONCE per step (loop-invariant
    scan operands — XLA hoists them out of the layer loop, §Perf: removes
    per-layer trig + fp32 position chains).  Handles M-RoPE section gather.
    Returns (B, S, Dh/2) fp32 pairs."""
    freqs = rope_freqs(cfg.d_head, cfg.rope_theta)        # (Dh/2,)
    if cfg.mrope:
        sec_id = jnp.repeat(jnp.arange(3), jnp.array(cfg.mrope_sections),
                            total_repeat_length=cfg.d_head // 2)
        pos = jnp.take(jnp.moveaxis(positions, 0, -1), sec_id, axis=-1)
        angles = pos.astype(jnp.float32) * freqs
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope_tables(x: jnp.ndarray, tables) -> jnp.ndarray:
    """x: (B, S, H, Dh); tables from `rope_tables`."""
    cos, sin = tables
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0) -> jnp.ndarray:
    off = jnp.asarray(offset)
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = pos + (off[:, None] if off.ndim else off)   # per-row offsets allowed
    pos = jnp.broadcast_to(pos, (batch, seq)).astype(jnp.int32)
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, batch, seq))  # text-only default
    return pos
