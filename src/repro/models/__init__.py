"""Composable model substrate: every assigned architecture family in raw JAX."""

from .config import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    reduced,
)
from .transformer import (  # noqa: F401
    encode,
    forward,
    init_cache,
    init_lm,
    lm_loss,
    logits_fn,
    stack_layout,
)
