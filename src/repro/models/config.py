"""Model & input-shape configuration.

One `ModelConfig` covers all 10 assigned architecture families; per-arch
constructors live in `repro.configs.<id>`.  `ShapeConfig` describes the
assigned input shapes (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds used in layer patterns.
BLOCK_ATTN = "attn"        # attention + FFN transformer block
BLOCK_MOE = "moe"          # attention + MoE-FFN block
BLOCK_MAMBA2 = "mamba2"    # Mamba2 SSD block
BLOCK_MLSTM = "mlstm"      # xLSTM mLSTM block
BLOCK_SLSTM = "slstm"      # xLSTM sLSTM block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 → d_model // n_heads

    # --- attention ---
    qkv_bias: bool = False          # Qwen1.5-style biases on Q/K/V
    rope_theta: float = 10_000.0
    mrope: bool = False             # Qwen2-VL multimodal RoPE (3D positions)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w dims of d_head/2

    # --- ffn ---
    ffn_type: str = "swiglu"        # swiglu | gelu | relu2
    ffn_bias: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_coef: float = 1e-2

    # --- SSM / recurrent ---
    ssm_state: int = 0              # Mamba2 state dim N
    ssm_conv: int = 4               # depthwise conv width
    ssm_expand: int = 2             # Mamba2 d_inner = expand * d_model
    ssm_chunk: int = 64             # SSD chunk length
    mlstm_chunk: int = 256          # chunkwise-mLSTM chunk length
    mamba_headdim: int = 64         # Mamba2 per-head dim P
    qkv_block: int = 4              # xLSTM block-diagonal q/k/v blocksize
    slstm_expand: int = 1           # sLSTM hidden = slstm_expand · d_model
    # Layer pattern for hybrid / xLSTM stacks.  None → uniform family block.
    # e.g. zamba2: mamba2 everywhere + a SHARED attention block every k layers.
    block_pattern: Optional[Tuple[str, ...]] = None
    shared_attn_every: int = 0      # zamba2: shared attn block period (0=off)

    # --- encoder-decoder ---
    n_encoder_layers: int = 0       # >0 → enc-dec (seamless)
    frontend_stub: bool = False     # audio/vision frontend replaced by embeds

    # --- vlm ---
    vision_stub_patches: int = 0    # #patch embeddings provided by input stub

    # --- numerics & misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    remat: str = "block"            # none | block (checkpoint each layer)
    scan_layers: bool = True        # lax.scan over uniform layer stacks
    optimizer: str = "adamw"        # adamw | adafactor | adam8bit
    attn_impl: str = "ref"          # ref | flash | flash_decode (Pallas)
    ssm_impl: str = "ref"           # ref | pallas
    bf16_cotangent: bool = False    # §Perf: cast backward activations to bf16
    hoist_rope: bool = False        # §Perf: compute RoPE tables once per step
    psum_barrier: bool = False      # §Perf: stop f32-convert hoisting above TP psums

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family needs n_experts/top_k")

    # ------------------------------------------------------------- derived
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_pattern(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            if len(self.block_pattern) != self.n_layers:
                raise ValueError("block_pattern length != n_layers")
            return self.block_pattern
        default = {
            "dense": BLOCK_ATTN, "encdec": BLOCK_ATTN, "vlm": BLOCK_ATTN,
            "moe": BLOCK_MOE, "ssm": BLOCK_MAMBA2, "hybrid": BLOCK_MAMBA2,
        }[self.family]
        return tuple(default for _ in range(self.n_layers))

    def is_uniform(self) -> bool:
        pat = self.layer_pattern()
        return all(p == pat[0] for p in pat) and self.shared_attn_every == 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, dh = self.d_model, self.d_head
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * dh * n_q + 2 * d * dh * n_kv + dh * n_q * d
        if self.qkv_bias:
            attn += dh * (n_q + 2 * n_kv)
        def ffn_params(ff):
            mult = 3 if self.ffn_type == "swiglu" else 2
            return mult * d * ff
        total = 0
        for kind in self.layer_pattern():
            total += 2 * d  # norms
            if kind == BLOCK_ATTN:
                total += attn + ffn_params(self.d_ff)
            elif kind == BLOCK_MOE:
                total += attn + self.n_experts * ffn_params(self.d_ff) + d * self.n_experts
            elif kind == BLOCK_MAMBA2:
                d_in = self.ssm_expand * d
                h_ssm = max(1, d_in // self.mamba_headdim)
                proj_out = 2 * d_in + 2 * self.ssm_state + h_ssm
                conv_ch = d_in + 2 * self.ssm_state
                total += (d * proj_out + (self.ssm_conv + 1) * conv_ch
                          + 3 * h_ssm + d_in + d_in * d)
            elif kind == BLOCK_MLSTM:
                d_in = self.ssm_expand * d
                total += (d * 2 * d_in                     # up_proj
                          + (self.ssm_conv + 1) * d_in     # conv
                          + 3 * d_in * self.qkv_block      # block-diag q/k/v
                          + d_in * 2 * self.n_heads        # gates
                          + d_in + d_in * d)               # norm + down
            elif kind == BLOCK_SLSTM:
                d_in = self.slstm_expand * d
                p_head = d_in // self.n_heads
                ff = int(d_in * 4 / 3)
                total += (d * d_in + (self.ssm_conv + 1) * d_in
                          + 4 * d_in * d_in                # input gate weights
                          + 4 * d_in * p_head              # block-diag recurrent
                          + d_in + d_in * 2 * ff + ff * d)
        if self.shared_attn_every:
            total += attn + ffn_params(self.d_ff)  # one shared block
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + ffn_params(self.d_ff) + 2 * d)
            total += self.n_layers * (attn + d)  # cross-attention + norm
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.shared_attn_every == 0 else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=16,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        vision_stub_patches=min(cfg.vision_stub_patches, 16),
        block_pattern=None,
        param_dtype="float32",
        compute_dtype="float32",
        name=cfg.name + "-smoke",
    )
    if cfg.block_pattern is not None:
        n = small["n_layers"]
        # Preserve the family mix on a short stack.
        kinds = list(dict.fromkeys(cfg.block_pattern))  # unique, ordered
        small["block_pattern"] = tuple(kinds[i % len(kinds)] for i in range(n))
    if cfg.mrope:
        small["mrope_sections"] = (8, 4, 4)  # sums to d_head/2 = 16
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
