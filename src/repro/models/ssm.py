"""Mamba2 (SSD — state-space duality) blocks for zamba2-7b / hybrid stacks.

Selective state space per head p with state size N:

    S_t = exp(A·dt_t)·S_{t-1} + dt_t · x_t ⊗ B_t          (S: P×N)
    y_t = C_t · S_t + D · x_t

Training/prefill uses the chunked algorithm (intra-chunk quadratic form +
inter-chunk state recurrence via `lax.scan`); decode carries (conv window,
state) and steps in O(P·N).  The chunked jnp path is the oracle for the
`repro.kernels.ssm_scan` Pallas kernel.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .config import ModelConfig
from .layers import dtype_of, init_linear, rms_norm


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.mamba_headdim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig, dtype) -> Dict:
    d_inner, H, N = _dims(cfg)
    d = cfg.d_model
    k_in, k_conv, k_out, k_a, k_dt = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * N  # conv over (x, B, C)
    # A ∈ [1, 16] log-init (Mamba2 default), dt bias ≈ softplus⁻¹(0.005…0.1).
    a_init = jnp.exp(jax.random.uniform(k_a, (H,), minval=jnp.log(1.0), maxval=jnp.log(16.0)))
    dt0 = jnp.exp(jax.random.uniform(k_dt, (H,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    return {
        "in_proj": init_linear(k_in, d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k_conv, (cfg.ssm_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(a_init).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt0)).astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": init_linear(k_out, d_inner, d, dtype, scale=d_inner ** -0.5),
    }


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B,S,C), w: (W,C).  ``state`` is the
    trailing W−1 inputs from the previous call (decode); returns new state."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    return out, xp[:, -(W - 1):]


def ssd_chunked(
    x: jnp.ndarray,        # (B, S, H, P)
    Bm: jnp.ndarray,       # (B, S, N)
    Cm: jnp.ndarray,       # (B, S, N)
    dt: jnp.ndarray,       # (B, S, H)  (post-softplus)
    A_log: jnp.ndarray,    # (H,)
    D: jnp.ndarray,        # (H,)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        raise ValueError(f"seq {S} not divisible by chunk {chunk}")
    nc = S // chunk
    f32 = jnp.float32
    xc = x.reshape(B, nc, chunk, H, P).astype(f32)
    Bc = Bm.reshape(B, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(B, nc, chunk, N).astype(f32)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    la = -jnp.exp(A_log.astype(f32)) * dtc                      # (B,nc,L,H) log decay
    cum = jnp.cumsum(la, axis=2)                                # inclusive cumsum

    # Intra-chunk quadratic term: w[i,j] = exp(cum_i - cum_j)·dt_j for j ≤ i.
    with jax.named_scope("kscope_ssd"):
        li = cum[:, :, :, None, :]                              # (B,nc,L,1,H)
        lj = cum[:, :, None, :, :]                              # (B,nc,1,L,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri[None, None, :, :, None], jnp.exp(li - lj), 0.0)
        w = w * dtc[:, :, None, :, :]                           # (B,nc,i,j,H)
        g = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)               # (B,nc,i,j)
        y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", g, w, xc)

    # Inter-chunk: scan states across chunks.
    decay_end = jnp.exp(cum[:, :, -1])                          # (B,nc,H)
    # Contribution of step j to the chunk-final state: exp(cum_L - cum_j)·dt_j.
    wL = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                 # (B,nc,L,H)
    chunk_state = jnp.einsum("bclh,bclhp,bcln->bchpn", wL, xc, Bc)

    def step(S_prev, inputs):
        dec, cs = inputs                                        # (B,H), (B,H,P,N)
        S_new = S_prev * dec[..., None, None] + cs
        return S_new, S_prev

    S0 = init_state.astype(f32) if init_state is not None else jnp.zeros((B, H, P, N), f32)
    S_final, S_starts = jax.lax.scan(
        step,
        S0,
        (jnp.moveaxis(decay_end, 1, 0), jnp.moveaxis(chunk_state, 1, 0)),
    )
    S_starts = jnp.moveaxis(S_starts, 0, 1)                     # (B,nc,H,P,N) state at chunk start
    # y_inter_i = exp(cum_i) · C_i · S_start
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc, jnp.exp(cum), S_starts)
    y = y_intra + y_inter + xc * D.astype(f32)[None, None, None, :, None]
    return y.reshape(B, S, H, P), S_final


def ssd_reference(x, Bm, Cm, dt, A_log, D, init_state=None):
    """Step-by-step scan oracle (O(S) sequential) for testing the chunked path."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    A = -jnp.exp(A_log.astype(f32))

    def step(S_prev, inputs):
        xt, bt, ct, dtt = inputs
        dec = jnp.exp(A[None] * dtt)                            # (B,H)
        S_new = S_prev * dec[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt.astype(f32), bt.astype(f32), dtt)
        y = jnp.einsum("bhpn,bn->bhp", S_new, ct.astype(f32))
        return S_new, y

    S0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), f32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0).astype(f32))
    S_final, ys = jax.lax.scan(step, S0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y, S_final


def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, H, N = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * N), dtype_of(cfg.compute_dtype)),
        "state": jnp.zeros((batch, H, cfg.mamba_headdim, N), jnp.float32),
    }


def mamba2_block(
    params: Dict,
    x: jnp.ndarray,                 # (B, S, d) — pre-normed input
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 mixer.  With ``cache`` (decode) S may be 1; state is carried."""
    d_inner, H, N = _dims(cfg)
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    proj = jnp.einsum("bsd,dk->bsk", x.astype(cd), params["in_proj"]["w"].astype(cd))
    proj = constrain(proj, ("dp", None, "tp"))
    z, xs, Bm, Cm, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, conv_state = causal_conv(
        conv_in, params["conv_w"].astype(cd), params["conv_b"].astype(cd),
        None if cache is None else cache["conv"],
    )
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    xh = xs.reshape(B, S, H, cfg.mamba_headdim)

    init_state = None if cache is None else cache["state"]
    if S == 1:
        # Decode: exact single-step recurrence.
        y, state = ssd_reference(xh, Bm, Cm, dt, params["A_log"], params["D"],
                                 init_state=init_state)
    elif cfg.ssm_impl == "pallas" and S % cfg.ssm_chunk == 0 and init_state is None:
        from repro.kernels import ops as kops
        y, state = kops.ssm_scan(xh, Bm, Cm, dt, params["A_log"], params["D"],
                                 chunk=cfg.ssm_chunk)
    else:
        # Train / prefill: chunked scan (state carried for prefill).
        chunk = cfg.ssm_chunk if S % cfg.ssm_chunk == 0 else 1
        y, state = ssd_chunked(xh, Bm, Cm, dt, params["A_log"], params["D"], chunk,
                               init_state=init_state)
    new_cache = None if cache is None else {"conv": conv_state, "state": state}

    y = y.reshape(B, S, d_inner).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"]["w"].astype(cd))
    return out, new_cache
