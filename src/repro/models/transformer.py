"""Decoder-LM assembly for all architecture families.

Layer stacks are grouped into their minimal repeating *period* and scanned
with `jax.lax.scan` (small HLO even for 80-layer/1T-param programs — vital
for the CPU-hosted dry-run), with any remainder layers unrolled:

  * dense / MoE / VLM / enc-dec decoder: period 1
  * xlstm-1.3b: period 8 (7× mLSTM + 1× sLSTM)
  * zamba2-7b: period `shared_attn_every` with ONE weight-shared attention
    block applied at the start of each period (its KV caches are per-depth).

`forward` covers train / prefill / decode via the optional (cache,
cache_index) pair; MoE aux losses ride the scan carry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .attention import _self_attention_math, attention, init_attention
from .config import (
    BLOCK_ATTN,
    BLOCK_MAMBA2,
    BLOCK_MLSTM,
    BLOCK_MOE,
    BLOCK_SLSTM,
    ModelConfig,
)
from .ffn import ffn, init_ffn
from .layers import (
    apply_linear,
    bf16_cotangent_barrier,
    dtype_of,
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    positions_for,
    rms_norm,
    rope_tables,
    unembed,
)
from .moe import init_moe, moe_ffn
from .ssm import init_mamba2, init_ssm_cache, mamba2_block
from .xlstm import (
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mlstm_block,
    slstm_block,
)


# ---------------------------------------------------------------- layout --
@dataclasses.dataclass(frozen=True)
class StackLayout:
    kinds: Tuple[str, ...]       # full layer pattern
    period: int
    n_full: int                  # scanned periods
    tail: Tuple[str, ...]        # unrolled remainder kinds
    shared_attn: bool

    @property
    def period_kinds(self) -> Tuple[str, ...]:
        return self.kinds[: self.period]


def _minimal_period(pattern: Tuple[str, ...]) -> int:
    for p in range(1, len(pattern) + 1):
        if all(pattern[i] == pattern[i % p] for i in range(len(pattern))):
            return p
    return len(pattern)


def stack_layout(cfg: ModelConfig) -> StackLayout:
    pattern = cfg.layer_pattern()
    p = _minimal_period(pattern)
    if cfg.shared_attn_every:
        p = max(p, cfg.shared_attn_every)
    if not cfg.scan_layers:
        p = len(pattern)
    n_full = len(pattern) // p
    tail = pattern[n_full * p:]
    return StackLayout(pattern, p, n_full, tail, bool(cfg.shared_attn_every))


# ------------------------------------------------------------------ init --
def init_block(key, cfg: ModelConfig, kind: str, dtype, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind in (BLOCK_ATTN, BLOCK_MOE):
        p = {
            "norm1": init_rmsnorm(d, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "norm2": init_rmsnorm(d, dtype),
        }
        if kind == BLOCK_MOE:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_ffn(ks[1], cfg, dtype)
        if cross:
            p["norm_cross"] = init_rmsnorm(d, dtype)
            p["cross"] = init_attention(ks[2], cfg, dtype, cross=True)
        return p
    if kind == BLOCK_MAMBA2:
        return {"norm1": init_rmsnorm(d, dtype), "mixer": init_mamba2(ks[0], cfg, dtype)}
    if kind == BLOCK_MLSTM:
        return {"norm1": init_rmsnorm(d, dtype), "mixer": init_mlstm(ks[0], cfg, dtype)}
    if kind == BLOCK_SLSTM:
        return {"norm1": init_rmsnorm(d, dtype), "mixer": init_slstm(ks[0], cfg, dtype)}
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     cross_len: int = 0) -> Dict:
    cd = dtype_of(cfg.compute_dtype)
    if kind in (BLOCK_ATTN, BLOCK_MOE):
        shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
        c = {"attn": {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd)}}
        if cross_len:
            xs = (batch, cross_len, cfg.n_kv_heads, cfg.d_head)
            c["cross"] = {"k": jnp.zeros(xs, cd), "v": jnp.zeros(xs, cd)}
        return c
    if kind == BLOCK_MAMBA2:
        return {"mixer": init_ssm_cache(cfg, batch)}
    if kind == BLOCK_MLSTM:
        return {"mixer": init_mlstm_cache(cfg, batch)}
    if kind == BLOCK_SLSTM:
        return {"mixer": init_slstm_cache(cfg, batch)}
    raise ValueError(kind)


def _stack_trees(trees: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig) -> Dict:
    """Full parameter pytree.  Scanned period params carry a leading
    (n_full,) axis; tail layers and the shared-attn block are unstacked."""
    dtype = dtype_of(cfg.param_dtype)
    layout = stack_layout(cfg)
    k_embed, k_blocks, k_shared, k_enc, k_head = jax.random.split(key, 5)
    cross = cfg.n_encoder_layers > 0
    params: Dict[str, Any] = {"embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype)}

    scan_params = {}
    block_keys = jax.random.split(k_blocks, max(layout.n_full, 1) * layout.period + len(layout.tail))
    for j, kind in enumerate(layout.period_kinds):
        per = [init_block(block_keys[i * layout.period + j], cfg, kind, dtype, cross)
               for i in range(layout.n_full)]
        scan_params[f"pos{j}"] = _stack_trees(per)
    params["blocks"] = scan_params
    params["tail"] = [
        init_block(block_keys[layout.n_full * layout.period + t], cfg, kind, dtype, cross)
        for t, kind in enumerate(layout.tail)
    ]
    if layout.shared_attn:
        params["shared_attn"] = {
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(k_shared, cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_ffn(jax.random.fold_in(k_shared, 1), cfg, dtype),
        }
    if cfg.n_encoder_layers:
        enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
        params["encoder"] = {
            "blocks": _stack_trees(
                [init_block(ek, cfg, BLOCK_ATTN, dtype) for ek in enc_keys]),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, cross_len: int = 0,
               per_slot_index: bool = False) -> Dict:
    layout = stack_layout(cfg)
    idx = jnp.zeros((batch,) if per_slot_index else (), jnp.int32)
    cache: Dict[str, Any] = {"blocks": {}, "tail": [], "index": idx}
    for j, kind in enumerate(layout.period_kinds):
        per = [init_block_cache(cfg, kind, batch, max_len, cross_len)
               for _ in range(layout.n_full)]
        cache["blocks"][f"pos{j}"] = _stack_trees(per)
    cache["tail"] = [init_block_cache(cfg, kind, batch, max_len, cross_len)
                     for kind in layout.tail]
    if layout.shared_attn:
        shared = [init_block_cache(cfg, BLOCK_ATTN, batch, max_len)
                  for _ in range(layout.n_full)]
        cache["shared"] = _stack_trees(shared)
        n_tail_shared = sum(1 for t in range(len(layout.tail))
                            if (layout.n_full * layout.period + t) % cfg.shared_attn_every == 0)
        cache["tail_shared"] = [init_block_cache(cfg, BLOCK_ATTN, batch, max_len)
                                for _ in range(n_tail_shared)]
    return cache


def reset_slot(cache: Dict, slot) -> Dict:
    """Zero one batch slot across the whole cache (continuous batching:
    recurrent SSM/xLSTM states carry no positional mask, so a freed slot
    must be wiped before admitting a new request)."""
    out = dict(cache)
    out["index"] = cache["index"].at[slot].set(0)
    out["blocks"] = jax.tree.map(lambda x: x.at[:, slot].set(0), cache["blocks"])
    out["tail"] = jax.tree.map(lambda x: x.at[slot].set(0), cache["tail"])
    if "shared" in cache:
        out["shared"] = jax.tree.map(lambda x: x.at[:, slot].set(0), cache["shared"])
    if "tail_shared" in cache:
        out["tail_shared"] = jax.tree.map(lambda x: x.at[slot].set(0),
                                          cache["tail_shared"])
    return out


# --------------------------------------------------------------- forward --
def _bar(x, cfg):
    return bf16_cotangent_barrier(x) if cfg.bf16_cotangent else x


def _psum_bar(x, cfg):
    """Keep the TP all-reduce of a row-parallel projection in bf16: without
    this, XLA hoists the next norm's f32 convert above the psum and ships
    2× the bytes (measured on the 110B cell)."""
    if cfg.psum_barrier:
        return jax.lax.optimization_barrier(x)
    return x


def _attn_block(bp, x, cfg, positions, cache, index, encoder_out, kind,
                rope_cache=None):
    aux = jnp.zeros((), jnp.float32)
    h = _bar(rms_norm(x, bp["norm1"]["scale"], cfg.norm_eps), cfg)
    a, attn_cache = attention(
        bp["attn"], h, cfg, positions, causal=True,
        cache=None if cache is None else cache["attn"],
        cache_index=None if cache is None else index,
        rope_cache=rope_cache,
    )
    x = x + _psum_bar(a, cfg)
    new_cache = None if cache is None else dict(cache, attn=attn_cache)
    if "cross" in bp:
        cd = dtype_of(cfg.compute_dtype)
        hc = _bar(rms_norm(x, bp["norm_cross"]["scale"], cfg.norm_eps), cfg)
        if encoder_out is not None:
            # Train / prefill: project the encoder memory; cache it for decode.
            ck = apply_linear(bp["cross"]["wk"], encoder_out, cd)
            cv = apply_linear(bp["cross"]["wv"], encoder_out, cd)
            ck = ck.reshape(*ck.shape[:-1], cfg.n_kv_heads, cfg.d_head)
            cv = cv.reshape(*cv.shape[:-1], cfg.n_kv_heads, cfg.d_head)
            if new_cache is not None:
                new_cache["cross"] = {"k": ck, "v": cv}
        else:
            if cache is None or "cross" not in cache:
                raise ValueError("decode without encoder_out needs a cross cache")
            ck, cv = cache["cross"]["k"], cache["cross"]["v"]
        q = apply_linear(bp["cross"]["wq"], hc, cd)
        q = q.reshape(*q.shape[:-1], cfg.n_heads, cfg.d_head)
        o = _self_attention_math(q, ck, cv, causal=False)
        c = apply_linear(bp["cross"]["wo"], o.reshape(*hc.shape[:-1], -1), cd)
        x = x + c
    h2 = _bar(rms_norm(x, bp["norm2"]["scale"], cfg.norm_eps), cfg)
    if kind == BLOCK_MOE:
        f, moe_aux, _ = moe_ffn(bp["moe"], h2, cfg)
        aux = aux + moe_aux
    else:
        f = ffn(bp["ffn"], h2, cfg)
    return x + _psum_bar(f, cfg), new_cache, aux


def apply_block(kind, bp, x, cfg, *, positions, cache, index, encoder_out=None,
                rope_cache=None):
    if kind in (BLOCK_ATTN, BLOCK_MOE):
        return _attn_block(bp, x, cfg, positions, cache, index, encoder_out, kind,
                           rope_cache)
    h = _bar(rms_norm(x, bp["norm1"]["scale"], cfg.norm_eps), cfg)
    mixer_cache = None if cache is None else cache["mixer"]
    if kind == BLOCK_MAMBA2:
        m, mc = mamba2_block(bp["mixer"], h, cfg, mixer_cache)
    elif kind == BLOCK_MLSTM:
        m, mc = mlstm_block(bp["mixer"], h, cfg, mixer_cache)
    elif kind == BLOCK_SLSTM:
        m, mc = slstm_block(bp["mixer"], h, cfg, mixer_cache)
    else:
        raise ValueError(kind)
    new_cache = None if cache is None else {"mixer": mc}
    return x + _psum_bar(m, cfg), new_cache, jnp.zeros((), jnp.float32)


def _apply_shared(shared, x, cfg, positions, cache, index, rope_cache=None):
    """Zamba2's weight-shared attention block (own per-depth KV cache)."""
    h = _bar(rms_norm(x, shared["norm1"]["scale"], cfg.norm_eps), cfg)
    a, attn_cache = attention(
        shared["attn"], h, cfg, positions, causal=True,
        cache=None if cache is None else cache["attn"],
        cache_index=None if cache is None else index,
        rope_cache=rope_cache,
    )
    x = x + a
    h2 = _bar(rms_norm(x, shared["norm2"]["scale"], cfg.norm_eps), cfg)
    x = x + ffn(shared["ffn"], h2, cfg)
    return x, None if cache is None else dict(cache, attn=attn_cache)


def forward(
    params: Dict,
    tokens: Optional[jnp.ndarray],       # (B, S) int32; None if embeds given
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Dict] = None,
    encoder_out: Optional[jnp.ndarray] = None,
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, P, d) prefix stub
    input_embeds: Optional[jnp.ndarray] = None,   # bypass embedding (encoder stubs)
    decoding: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (hidden (B,S,d) — NOT logits; see `logits`/`lm_loss` —,
    new_cache, aux_loss)."""
    cd = dtype_of(cfg.compute_dtype)
    layout = stack_layout(cfg)
    if input_embeds is not None:
        x = input_embeds.astype(cd)
    else:
        x = embed(params["embed"], tokens, cd)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cd), x], axis=1)
    x = constrain(x, ("dp", None, None))
    B, S, _ = x.shape
    if positions is None:
        offset = cache["index"] if cache is not None else 0
        positions = positions_for(cfg, B, S, offset)
    index = cache["index"] if cache is not None else None
    rope_cache = rope_tables(cfg, positions) if cfg.hoist_rope else None

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict] = {"blocks": {}, "tail": []} if cache is not None else None

    # ------------------------------------------------------ scanned periods
    if layout.n_full:
        def period_fn(carry, xs):
            x, aux = carry
            x = constrain(x, ("dp", None, None))
            if cfg.bf16_cotangent:
                x = bf16_cotangent_barrier(x)
            block_slice, cache_slice, shared_cache = xs
            if layout.shared_attn:
                x, sc = _apply_shared(params["shared_attn"], x, cfg, positions,
                                      shared_cache, index, rope_cache)
            else:
                sc = shared_cache
            new_cslice = {}
            for j, kind in enumerate(layout.period_kinds):
                cj = None if cache_slice is None else cache_slice[f"pos{j}"]
                x, cj_new, a = apply_block(
                    kind, block_slice[f"pos{j}"], x, cfg,
                    positions=positions, cache=cj, index=index,
                    encoder_out=encoder_out, rope_cache=rope_cache)
                new_cslice[f"pos{j}"] = cj_new
                aux = aux + a
            return (x, aux), (new_cslice if cache is not None else 0,
                              sc if (cache is not None and layout.shared_attn) else 0)

        body = period_fn
        if cfg.remat == "block":
            body = jax.checkpoint(period_fn, prevent_cse=False)
        elif cfg.remat == "dots":
            # Save matmul outputs: backward recomputes only elementwise ops —
            # in particular the TP psums of wo/w_down outputs are NOT re-run
            # (§Perf: trades ~(B,S,d)·layers HBM for collective wire).
            body = jax.checkpoint(
                period_fn, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_saveable)
        xs = (
            params["blocks"],
            cache["blocks"] if cache is not None else None,
            cache.get("shared") if (cache is not None and layout.shared_attn) else None,
        )
        (x, aux_total), (cache_out, shared_out) = jax.lax.scan(
            body, (x, aux_total), xs, length=layout.n_full)
        if cache is not None:
            new_cache["blocks"] = cache_out
            if layout.shared_attn:
                new_cache["shared"] = shared_out

    # --------------------------------------------------------- tail layers
    shared_i = 0
    for t, kind in enumerate(layout.tail):
        layer_idx = layout.n_full * layout.period + t
        if layout.shared_attn and layer_idx % cfg.shared_attn_every == 0:
            sc = cache["tail_shared"][shared_i] if cache is not None else None
            x, sc_new = _apply_shared(params["shared_attn"], x, cfg, positions, sc, index)
            if cache is not None:
                new_cache.setdefault("tail_shared", []).append(sc_new)
            shared_i += 1
        cj = cache["tail"][t] if cache is not None else None
        x, cj_new, a = apply_block(kind, params["tail"][t], x, cfg,
                                   positions=positions, cache=cj, index=index,
                                   encoder_out=encoder_out, rope_cache=rope_cache)
        x = constrain(x, ("dp", None, None))
        aux_total = aux_total + a
        if cache is not None:
            new_cache["tail"].append(cj_new)

    if cfg.bf16_cotangent:
        x = bf16_cotangent_barrier(x)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cache is not None:
        new_cache["index"] = cache["index"] + S
    return x, new_cache, aux_total


def logits_fn(params: Dict, hidden: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return unembed(params["embed"], hidden, dtype_of(cfg.logit_dtype))
    return apply_linear(params["unembed"], hidden, dtype_of(cfg.logit_dtype))


# --------------------------------------------------------------- encoder --
def encode(params: Dict, input_embeds: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Bidirectional encoder over stub frontend embeddings (B, S_enc, d)."""
    cd = dtype_of(cfg.compute_dtype)
    x = input_embeds.astype(cd)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, block):
        x = constrain(x, ("dp", None, None))
        h = rms_norm(x, block["norm1"]["scale"], cfg.norm_eps)
        a, _ = attention(block["attn"], h, cfg, positions, causal=False)
        x = x + a
        h2 = rms_norm(x, block["norm2"]["scale"], cfg.norm_eps)
        return x + ffn(block["ffn"], h2, cfg), 0

    fn = body
    if cfg.remat == "block":
        fn = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat == "dots":
        fn = jax.checkpoint(body, prevent_cse=False,
                            policy=jax.checkpoint_policies.dots_saveable)
    x, _ = jax.lax.scan(fn, x, params["encoder"]["blocks"])
    return rms_norm(x, params["encoder"]["final_norm"]["scale"], cfg.norm_eps)


# ------------------------------------------------------------------ loss --
def lm_loss(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    loss_chunk: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE.  batch: inputs/targets (B,S) [+ encoder_embeds /
    vision_embeds / positions].  ``loss_chunk`` bounds the logits
    materialization to (B, chunk, V) — essential for 150k–256k vocabs."""
    encoder_out = None
    if cfg.n_encoder_layers:
        encoder_out = encode(params, batch["encoder_embeds"], cfg)
    hidden, _, aux = forward(
        params, batch["inputs"], cfg,
        positions=batch.get("positions"),
        encoder_out=encoder_out,
        vision_embeds=batch.get("vision_embeds"),
    )
    targets = batch["targets"]
    if hidden.shape[1] != targets.shape[1]:
        # VLM: loss only over the text suffix.
        hidden = hidden[:, hidden.shape[1] - targets.shape[1]:]

    def ce(h_chunk, t_chunk):
        lg = constrain(logits_fn(params, h_chunk, cfg), ("dp", None, "tp"))
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, t_chunk[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    B, S, _ = hidden.shape
    if loss_chunk and S % loss_chunk == 0 and S > loss_chunk:
        nc = S // loss_chunk
        hs = hidden.reshape(B, nc, loss_chunk, -1).swapaxes(0, 1)
        ts = targets.reshape(B, nc, loss_chunk).swapaxes(0, 1)
        def body(tot, xt):
            h, t = xt
            return tot + ce(h, t), 0
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (hs, ts))
    else:
        total = ce(hidden, targets)
    n_tok = jnp.array(B * S, jnp.float32)
    loss = total / n_tok + aux
    return loss, {"loss": loss, "ce": total / n_tok, "aux": aux}
