"""Mixture-of-Experts FFN: top-k token-choice router + capacity-bounded
sort-based dispatch (DBRX 16e/top-4, Kimi-K2 384e/top-8).

Single-device reference lives here; the expert-parallel version
(`repro.parallel.moe_ep`) wraps the same math in `shard_map` with explicit
all-to-alls and must match it exactly (tested).  The sort-based dispatch
gives FLOPs ∝ active-expert compute (× capacity factor), which keeps the
dry-run roofline honest — a dense all-experts einsum would overcount by
E/top_k (48× for Kimi).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .config import ModelConfig
from .ffn import ffn, init_ffn
from .layers import dtype_of, init_linear


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    k_router, k_experts = jax.random.split(key)
    # Stacked expert FFNs: leaves get a leading (E,) axis.
    expert_keys = jax.random.split(k_experts, cfg.n_experts)
    experts = jax.vmap(lambda k: init_ffn(k, cfg, dtype))(expert_keys)
    return {
        "router": init_linear(k_router, cfg.d_model, cfg.n_experts, jnp.float32),
        "experts": experts,
    }


def router_probs(params, x_flat, cfg: ModelConfig):
    """fp32 router; returns (logits, probs, top-k probs/ids) with the top-k
    weights renormalized (standard for top-k>1 routers)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    return logits, probs, top_p, top_ids


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    return max(1, int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                                / cfg.n_experts)))


def build_dispatch(top_ids, top_p, n_tokens: int, cfg: ModelConfig, cap: int):
    """Sort-based dispatch plan.

    Returns (token_src, buffer_idx, keep, weight) flat arrays of length
    ``n_tokens*top_k``, where ``buffer_idx`` addresses an (E*cap,) expert
    buffer and dropped assignments point at a dump slot E*cap.
    """
    k = cfg.top_k
    flat_e = top_ids.reshape(-1)                       # (T*k,)
    flat_w = top_p.reshape(-1)
    token_src = jnp.repeat(jnp.arange(n_tokens), k)    # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=cfg.n_experts)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n_tokens * k) - offsets[sorted_e]
    keep = rank < cap
    buffer_idx = jnp.where(keep, sorted_e * cap + rank, cfg.n_experts * cap)
    return token_src[order], buffer_idx, keep, flat_w[order]


def aux_losses(logits, probs, top_ids, cfg: ModelConfig):
    """Switch-style load-balance loss + router z-loss."""
    E = cfg.n_experts
    onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)  # (T,k,E)
    frac_dispatched = onehot.sum((0, 1)) / (onehot.shape[0] * cfg.top_k)
    mean_prob = probs.mean(0)
    balance = E * jnp.sum(frac_dispatched * mean_prob)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return cfg.aux_loss_coef * balance + cfg.router_z_loss * z, {
        "moe_balance": balance, "moe_zloss": z,
    }


def expert_ffn(expert_params, buf, cfg: ModelConfig):
    """Apply stacked expert FFNs: buf (E, C, d) → (E, C, d)."""
    cd = dtype_of(cfg.compute_dtype)
    b = buf.astype(cd)
    if cfg.ffn_type == "swiglu":
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", b, expert_params["w_gate"]["w"].astype(cd)))
        up = jnp.einsum("ecd,edf->ecf", b, expert_params["w_up"]["w"].astype(cd))
        return jnp.einsum("ecf,efd->ecd", gate * up, expert_params["w_down"]["w"].astype(cd))
    h = jnp.einsum("ecd,edf->ecf", b, expert_params["w_up"]["w"].astype(cd))
    h = jax.nn.gelu(h) if cfg.ffn_type == "gelu" else jax.nn.relu(h) ** 2
    return jnp.einsum("ecf,efd->ecd", h, expert_params["w_down"]["w"].astype(cd))


def moe_ffn(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """MoE FFN.  x: (B, S, d) → (out, aux_loss, metrics).

    With an active sharding context whose strategy selects ``ep_shardmap``,
    dispatch runs through explicit expert-parallel all-to-alls
    (`repro.parallel.moe_ep`); otherwise the sort-based single-program path
    below (XLA SPMD partitions it — measured badly for many-expert models,
    see EXPERIMENTS.md §Perf kimi iterations)."""
    from repro.parallel.context import current
    ctx = current()
    if ctx is not None:
        mesh, strat = ctx
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_ep = sizes.get(strat.tp, 1)
        if (getattr(strat, "moe", "auto_spmd") == "ep_shardmap"
                and n_ep > 1 and cfg.n_experts % n_ep == 0):
            from repro.parallel.moe_ep import moe_ffn_ep
            return moe_ffn_ep(params, x, cfg, mesh, strat)
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits, probs, top_p, top_ids = router_probs(params, xf, cfg)
    cap = capacity(T, cfg)
    token_src, buffer_idx, keep, weight = build_dispatch(top_ids, top_p, T, cfg, cap)

    buf = jnp.zeros((cfg.n_experts * cap + 1, d), x.dtype)
    buf = buf.at[buffer_idx].set(xf[token_src] * keep[:, None].astype(x.dtype))
    ebuf = constrain(buf[:-1].reshape(cfg.n_experts, cap, d), ("ep", None, None))
    y = expert_ffn(params["experts"], ebuf, cfg)
    y = jnp.concatenate([y.reshape(-1, d), jnp.zeros((1, d), y.dtype)])

    gathered = y[buffer_idx] * (weight * keep)[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[token_src].add(gathered)
    aux, metrics = aux_losses(logits, probs, top_ids, cfg)
    metrics["moe_drop_frac"] = 1.0 - keep.mean()
    return out.reshape(B, S, d), aux, metrics


def moe_ffn_dense_oracle(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """All-experts dense evaluation (no capacity drops) — tiny-shape oracle
    for testing the dispatch path when capacity_factor is large enough that
    nothing drops."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    _, _, top_p, top_ids = router_probs(params, xf, cfg)
    # (T, E): combined weight per expert.
    w = jnp.zeros((xf.shape[0], cfg.n_experts), jnp.float32)
    w = jnp.take_along_axis(w, top_ids, axis=1)  # zeros; replaced below
    w = jnp.zeros_like(w).at[
        jnp.arange(xf.shape[0])[:, None], top_ids
    ].set(top_p)
    # Evaluate every expert on every token.
    buf = jnp.broadcast_to(xf[None], (cfg.n_experts, xf.shape[0], d))
    y = expert_ffn(params["experts"], buf, cfg)  # (E, T, d)
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), w)
    return out.reshape(B, S, d).astype(x.dtype)
