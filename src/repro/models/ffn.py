"""Dense feed-forward variants: SwiGLU (Qwen/DBRX/Kimi), GELU (Seamless),
squared-ReLU (Nemotron-4)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .config import ModelConfig
from .layers import ACTIVATIONS, apply_linear, dtype_of, init_linear, relu2


def init_ffn(key, cfg: ModelConfig, dtype, d_ff: int = 0) -> Dict:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.ffn_type == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": init_linear(k1, d, d_ff, dtype, bias=cfg.ffn_bias),
            "w_up": init_linear(k2, d, d_ff, dtype, bias=cfg.ffn_bias),
            "w_down": init_linear(k3, d_ff, d, dtype, bias=cfg.ffn_bias,
                                  scale=d_ff ** -0.5),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_up": init_linear(k1, d, d_ff, dtype, bias=cfg.ffn_bias),
        "w_down": init_linear(k2, d_ff, d, dtype, bias=cfg.ffn_bias,
                              scale=d_ff ** -0.5),
    }


def ffn(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    cd = dtype_of(cfg.compute_dtype)
    if cfg.ffn_type == "swiglu":
        gate = jax.nn.silu(apply_linear(params["w_gate"], x, cd))
        up = constrain(apply_linear(params["w_up"], x, cd), ("dp", None, "tp"))
        return apply_linear(params["w_down"], gate * up, cd)
    act = ACTIVATIONS["gelu" if cfg.ffn_type == "gelu" else "relu2"]
    h = constrain(act(apply_linear(params["w_up"], x, cd)), ("dp", None, "tp"))
    return apply_linear(params["w_down"], h, cd)
