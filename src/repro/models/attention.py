"""Grouped-query attention with RoPE/M-RoPE, KV cache, and cross-attention.

The jnp path here is the *reference* implementation (and what the dry-run
lowers — XLA-native ops give clean HLO for the roofline analysis).  The
Pallas flash kernels in `repro.kernels` are drop-in replacements selected
with ``impl="flash"`` / ``impl="flash_decode"`` (validated in interpret mode
on CPU; TPU is the target).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .config import ModelConfig
from .layers import (apply_linear, apply_mrope, apply_rope, apply_rope_tables,
                     dtype_of, init_linear)

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, dh = cfg.d_model, cfg.d_head
    p = {
        "wq": init_linear(kq, d, cfg.n_heads * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(kk, d, cfg.n_kv_heads * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(kv, d, cfg.n_kv_heads * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ko, cfg.n_heads * dh, d, dtype, scale=(cfg.n_heads * dh) ** -0.5),
    }
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _split_heads(x, n_heads, d_head):
    return x.reshape(*x.shape[:-1], n_heads, d_head)


def _rope(cfg: ModelConfig, x, positions, rope_cache=None):
    if rope_cache is not None:
        return apply_rope_tables(x, rope_cache)
    if positions is None:
        return x
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def gqa_reference(
    q: jnp.ndarray,            # (B, Sq, Hq, Dh)
    k: jnp.ndarray,            # (B, Sk, Hkv, Dh)
    v: jnp.ndarray,            # (B, Sk, Hkv, Dh)
    causal: bool,
    q_offset: int | jnp.ndarray = 0,   # absolute position of q[0] (decode)
    kv_len: Optional[jnp.ndarray] = None,  # #valid cache entries (decode)
) -> jnp.ndarray:
    """Pure-jnp GQA attention; fp32 softmax.  Oracle for the flash kernels."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (Dh ** 0.5)
    Sk = k.shape[1]
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = None  # broadcastable to (B, Sq, Sk); offsets/lengths may be per-row
    if causal:
        qoff = jnp.broadcast_to(jnp.asarray(q_offset), (B,))
        mask = (qoff[:, None, None] + qpos[None, :, None]) >= kpos[None, None, :]
    if kv_len is not None:
        kvl = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
        valid = kpos[None, None, :] < kvl[:, None, None]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _flash_fwd_math(q, k, v, causal, q_offset, kv_len, q_chunk, k_chunk):
    """Online-softmax forward.  q: (B,Sq,Hq,Dh) → (out, lse (B,kv,G,Sq)).
    Pure XLA ops — `repro.kernels.flash_attention` is the Pallas twin with
    explicit VMEM tiling."""
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = Dh ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, q_chunk, Hkv, G, Dh), 1, 0).astype(jnp.float32)
    kb = jnp.moveaxis(k.reshape(B, nk, k_chunk, Hkv, Dh), 1, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v.reshape(B, nk, k_chunk, Hkv, Dh), 1, 0).astype(jnp.float32)

    def per_q(qi, q_blk):  # q_blk: (B, qc, Hkv, G, Dh)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def per_k(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk) * scale
            kpos = kj * k_chunk + jnp.arange(k_chunk)
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if kv_len is not None:
                mask &= (kpos < kv_len)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgqt,btkd->bkgqd", p, v_blk)
            return (m_new, l_new, acc_new), 0

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(per_k, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,kv,G,qc,Dh)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))                # (B,kv,G,qc)
        return jnp.moveaxis(out, 3, 1), lse

    with jax.named_scope("kscope_flash_fwd"):
        out, lse = jax.vmap(per_q)(jnp.arange(nq), qb)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, Dh).astype(q.dtype)
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, Hkv, G, Sq)        # (B,kv,G,nq·qc)
    return out, lse


def chunked_attention(q, k, v, *, causal, q_offset=0, kv_len=None,
                      q_chunk: int = 1024, k_chunk: int = 1024):
    """Forward-only online-softmax attention (prefill / encoder paths may
    carry traced offsets/lengths; training uses `flash_attention_jnp`)."""
    q_chunk = min(q_chunk, q.shape[1])
    k_chunk = min(k_chunk, k.shape[1])
    if q.shape[1] % q_chunk or k.shape[1] % k_chunk:
        return gqa_reference(q, k, v, causal, q_offset, kv_len)
    out, _ = _flash_fwd_math(q, k, v, causal, q_offset, kv_len, q_chunk, k_chunk)
    return out


# ---------------------------------------------------------- flash (train) --
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_jnp(q, k, v, causal: bool, q_chunk: int, k_chunk: int):
    """Flash attention with a flash *backward* (recompute probabilities per
    block from the saved log-sum-exp instead of storing them) — without this
    the scan backward stashes every (qc × kc) probability block and a 4k
    train step needs tens of GB per layer."""
    out, _ = _flash_fwd_math(q, k, v, causal, 0, None, q_chunk, k_chunk)
    return out


def _flash_fwd_rule(q, k, v, causal, q_chunk, k_chunk):
    out, lse = _flash_fwd_math(q, k, v, causal, 0, None, q_chunk, k_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, q_chunk, k_chunk, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = Dh ** -0.5
    f32 = jnp.float32
    qb = jnp.moveaxis(q.reshape(B, nq, q_chunk, Hkv, G, Dh), 1, 0).astype(f32)
    kb = jnp.moveaxis(k.reshape(B, nk, k_chunk, Hkv, Dh), 1, 0).astype(f32)
    vb = jnp.moveaxis(v.reshape(B, nk, k_chunk, Hkv, Dh), 1, 0).astype(f32)
    dob = jnp.moveaxis(dout.reshape(B, nq, q_chunk, Hkv, G, Dh), 1, 0).astype(f32)
    lseb = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, q_chunk), 3, 0)  # (nq,B,kv,G,qc)
    # D_i = Σ_d dout·out  (rowwise), per q position.
    delta = jnp.einsum("bsqgd,bsqgd->bqgs",
                       dout.reshape(B, Sq, Hkv, G, Dh).astype(f32),
                       out.reshape(B, Sq, Hkv, G, Dh).astype(f32))  # (B,kv,G,Sq)
    deltab = jnp.moveaxis(delta.reshape(B, Hkv, G, nq, q_chunk), 3, 0)

    def mask_for(qi, kj):
        qpos = qi * q_chunk + jnp.arange(q_chunk)
        kpos = kj * k_chunk + jnp.arange(k_chunk)
        return qpos[:, None] >= kpos[None, :]

    # Pass 1 — dq: vmap over q blocks, scan over k blocks.
    def dq_per_q(qi, q_blk, do_blk, lse_blk, dl_blk):
        def body(dq_acc, inputs):
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk) * scale
            if causal:
                s = jnp.where(mask_for(qi, kj)[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])
            dp = jnp.einsum("bqkgd,btkd->bkgqt", do_blk, v_blk)
            ds = p * (dp - dl_blk[..., None])
            dq_acc = dq_acc + jnp.einsum("bkgqt,btkd->bqkgd", ds, k_blk) * scale
            return dq_acc, 0
        dq0 = jnp.zeros_like(q_blk)
        dq_blk, _ = jax.lax.scan(body, dq0, (jnp.arange(nk), kb, vb))
        return dq_blk

    with jax.named_scope("kscope_flash_bwd"):
        dq = jax.vmap(dq_per_q)(jnp.arange(nq), qb, dob, lseb, deltab)
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, Sq, Hq, Dh).astype(q.dtype)

    # Pass 2 — dk/dv: vmap over k blocks, scan over q blocks.
    def dkv_per_k(kj, k_blk, v_blk):
        def body(carry, inputs):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, dl_blk = inputs
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk) * scale
            if causal:
                s = jnp.where(mask_for(qi, kj)[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])
            dv_acc = dv_acc + jnp.einsum("bkgqt,bqkgd->btkd", p, do_blk)
            dp = jnp.einsum("bqkgd,btkd->bkgqt", do_blk, v_blk)
            ds = p * (dp - dl_blk[..., None])
            dk_acc = dk_acc + jnp.einsum("bkgqt,bqkgd->btkd", ds, q_blk) * scale
            return (dk_acc, dv_acc), 0
        z = jnp.zeros_like(k_blk)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            body, (z, jnp.zeros_like(v_blk)),
            (jnp.arange(nq), qb, dob, lseb, deltab))
        return dk_blk, dv_blk

    with jax.named_scope("kscope_flash_bwd"):
        dk, dv = jax.vmap(dkv_per_k)(jnp.arange(nk), kb, vb)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, Sk, Hkv, Dh).astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, Sk, Hkv, Dh).astype(v.dtype)
    return dq, dk, dv


flash_attention_jnp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


#: Sequences at or above this length use the online-softmax path.
CHUNKED_ATTN_THRESHOLD = 2048
_Q_CHUNK = 1024
_K_CHUNK = 1024


def _self_attention_math(q, k, v, causal, q_offset=0, kv_len=None):
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq < CHUNKED_ATTN_THRESHOLD and Sk <= 2 * CHUNKED_ATTN_THRESHOLD:
        return gqa_reference(q, k, v, causal, q_offset, kv_len)
    qc, kc = min(_Q_CHUNK, Sq), min(_K_CHUNK, Sk)
    static_extras = isinstance(q_offset, int) and kv_len is None
    if static_extras and q_offset == 0 and Sq % qc == 0 and Sk % kc == 0:
        return flash_attention_jnp(q, k, v, causal, qc, kc)
    return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                             kv_len=kv_len, q_chunk=qc, k_chunk=kc)


def attention(
    params: Dict,
    x: jnp.ndarray,                      # (B, S, d)
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray],    # (B,S) or (3,B,S) for mrope
    *,
    causal: bool = True,
    kv_input: Optional[jnp.ndarray] = None,   # cross-attention memory (B,Sk,d)
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,  # scalar int32 write offset
    impl: Optional[str] = None,
    rope_cache=None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Self- or cross-attention with optional KV cache.

    Modes:
      * train/prefill: ``cache=None`` (prefill callers build a cache from the
        returned k/v via `prefill_cache`), full-sequence causal.
      * decode: ``cache`` + ``cache_index`` given, S == 1: write new k/v at
        ``cache_index`` and attend over the valid prefix.
      * cross: ``kv_input`` given (no cache, no causality).
    """
    impl = impl or cfg.attn_impl
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    kv_src = x if kv_input is None else kv_input
    q = _split_heads(apply_linear(params["wq"], x, cd), cfg.n_heads, cfg.d_head)
    k = _split_heads(apply_linear(params["wk"], kv_src, cd), cfg.n_kv_heads, cfg.d_head)
    v = _split_heads(apply_linear(params["wv"], kv_src, cd), cfg.n_kv_heads, cfg.d_head)
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))

    if kv_input is None:  # RoPE only applies to self-attention
        q = _rope(cfg, q, positions, rope_cache)
        k = _rope(cfg, k, positions, rope_cache)

    new_cache = None
    if cache is not None:
        # Decode: scatter this step's k/v at the write offset — a scalar in
        # lockstep decode, or per-row (B,) under continuous batching.
        idx = jnp.asarray(cache_index)
        if idx.ndim == 0:
            upd = lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), idx, axis=1)
        else:
            upd = lambda c, x: jax.vmap(
                lambda cb, xb, ib: jax.lax.dynamic_update_slice_in_dim(
                    cb, xb.astype(cb.dtype), ib, axis=0))(c, x, idx)
        k_cache = upd(cache["k"], k)
        v_cache = upd(cache["v"], v)
        new_cache = {"k": k_cache, "v": v_cache}
        kv_len = cache_index + S
        if impl == "flash_decode" and S == 1:
            from repro.kernels import ops as kops
            out = kops.decode_attention(q, k_cache, v_cache, kv_len)
        elif S == 1:
            # Single-step decode: prefix mask only.
            out = gqa_reference(q, k_cache, v_cache, causal=False, kv_len=kv_len)
        else:
            # Prefill-into-cache: causal with absolute offset.
            out = _self_attention_math(q, k_cache, v_cache, causal=True,
                                       q_offset=cache_index, kv_len=kv_len)
    else:
        if impl == "flash" and kv_input is None and causal:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True)
        else:
            out = _self_attention_math(q, k, v, causal=causal and kv_input is None)

    out = constrain(out, ("dp", None, "tp", None))
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    return apply_linear(params["wo"], out, cd), new_cache


def prefill_cache(cfg: ModelConfig, k: jnp.ndarray, v: jnp.ndarray, max_len: int) -> Dict:
    """Extend prefill-computed k/v to a full-size cache (right-padded)."""
    B, S, Hkv, Dh = k.shape
    pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
