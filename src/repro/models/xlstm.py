"""xLSTM blocks (xlstm-1.3b): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory with recurrent connections), both with exponential
gating and max-stabilizers, per Beck et al. 2024.

The stack follows the paper's [7:1] ratio — every 8th block is sLSTM, the
rest mLSTM (`repro.configs.xlstm_1_3b`).  Both recurrences are exact fp32
`lax.scan`s over time; mLSTM state is (C: P×P matrix, n: P, m: scalar) per
head, sLSTM state is (c, n, h, m) vectors per head.  sLSTM is inherently
sequential (recurrent weights on h), which the xLSTM paper itself notes —
there is no parallel form to exploit.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.context import constrain

from .config import ModelConfig
from .layers import dtype_of, init_linear, rms_norm
from .ssm import causal_conv


def _head_dims(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    return d_inner, d_inner // cfg.n_heads


def _slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = cfg.slstm_expand * cfg.d_model
    return d_inner, d_inner // cfg.n_heads


def init_blockdiag(key, d: int, block: int, dtype):
    """Block-diagonal linear (xLSTM q/k/v, blocksize 4): (d/bs, bs, bs)."""
    nb = d // block
    w = jax.random.normal(key, (nb, block, block)) * block ** -0.5
    return {"w": w.astype(dtype)}


def apply_blockdiag(p, x, cd):
    nb, bs, _ = p["w"].shape
    xb = x.reshape(*x.shape[:-1], nb, bs).astype(cd)
    y = jnp.einsum("...np,npq->...nq", xb, p["w"].astype(cd))
    return y.reshape(*x.shape)


# =============================================================== mLSTM ====
def init_mlstm(key, cfg: ModelConfig, dtype) -> Dict:
    d, (d_inner, P) = cfg.d_model, _head_dims(cfg)
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": init_linear(ks[0], d, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": init_blockdiag(ks[2], d_inner, cfg.qkv_block, dtype),
        "wk": init_blockdiag(ks[3], d_inner, cfg.qkv_block, dtype),
        "wv": init_blockdiag(ks[4], d_inner, cfg.qkv_block, dtype),
        "w_gates": init_linear(ks[5], d_inner, 2 * H, jnp.float32),  # ĩ, f̃ per head
        "norm_scale": jnp.ones((d_inner,), dtype),
        "down_proj": init_linear(ks[6], d_inner, d, dtype, scale=d_inner ** -0.5),
    }


def mlstm_recurrence(q, k, v, igate, fgate, init=None):
    """Stabilized mLSTM scan.  q,k,v: (B,S,H,P); gates: (B,S,H) pre-act.
    Returns (h (B,S,H,P), (C,n,m) final)."""
    B, S, H, P = q.shape
    f32 = jnp.float32
    q, k, v = (t.astype(f32) for t in (q, k, v))
    k = k / (P ** 0.5)
    lf = jax.nn.log_sigmoid(fgate.astype(f32))      # log forget gate
    li = igate.astype(f32)                          # log input gate (i = exp(ĩ))

    def step(carry, inputs):
        C, n, m = carry                             # (B,H,P,P), (B,H,P), (B,H)
        qt, kt, vt, lft, lit = inputs
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)               # stabilized gates
        ip = jnp.exp(lit - m_new)
        C_new = fp[..., None, None] * C + ip[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])    # v ⊗ k
        n_new = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhpq,bhq->bhp", C_new, qt)
        den = jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, qt))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = num / den[..., None]
        return (C_new, n_new, m_new), h

    if init is None:
        init = (jnp.zeros((B, H, P, P), f32), jnp.zeros((B, H, P), f32),
                jnp.zeros((B, H), f32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, lf, li))
    final, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1), final


def mlstm_chunked(q, k, v, igate, fgate, chunk: int, init=None):
    """Chunkwise-parallel stabilized mLSTM (the xLSTM paper's training form;
    cf. TFLA).  Same math as `mlstm_recurrence` (tested equal) but the
    matrix memory C only materializes at chunk boundaries — the per-step
    scan saves a (P×P) state per *token* for backward (1.4 TB/device at 4k
    sequence, measured), the chunkwise form one per chunk.

    q,k,v: (B,S,H,P); gates: (B,S,H) pre-activation.  Returns
    (h (B,S,H,P), (C,n,m) final)."""
    B, S, H, P = q.shape
    if S % chunk:
        raise ValueError(f"seq {S} % chunk {chunk} != 0")
    nc, L = S // chunk, chunk
    f32 = jnp.float32
    qs = q.reshape(B, nc, L, H, P).astype(f32)
    ks = (k.reshape(B, nc, L, H, P).astype(f32)) / (P ** 0.5)
    vs = v.reshape(B, nc, L, H, P).astype(f32)
    lf = jax.nn.log_sigmoid(fgate.astype(f32)).reshape(B, nc, L, H)
    li = igate.astype(f32).reshape(B, nc, L, H)
    b = jnp.cumsum(lf, axis=2)                      # (B,nc,L,H) inclusive
    btot = b[:, :, -1]                              # (B,nc,H)
    with jax.named_scope("kscope_mlstm"):
        # Intra-chunk log weights D_ij = b_i − b_j + ĩ_j  (j ≤ i).
        D = b[:, :, :, None, :] - b[:, :, None, :, :] + li[:, :, None, :, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri[None, None, :, :, None], D, -jnp.inf)
        m_intra = D.max(axis=3)                     # (B,nc,L,H)
        # Chunk-final state ingredients.
        wstate = btot[:, :, None, :] - b + li       # (B,nc,L,H)
        m_state = wstate.max(axis=2)                # (B,nc,H)
        s = jnp.einsum("bclhp,bcjhp->bchlj", qs, ks)  # (B,nc,H,L,L)

    def step(carry, xs_c):
        C, n, m = carry                             # (B,H,P,P),(B,H,P),(B,H)
        q_c, k_c, v_c, b_c, D_c, mi_c, s_c, bt_c, ws_c, ms_c = xs_c
        m_i = jnp.maximum(mi_c, b_c + m[:, None])                 # (B,L,H)
        Pij = jnp.exp(D_c - m_i[:, :, None])                      # (B,L,L,H)
        num = jnp.einsum("bhij,bijh,bjhp->bihp",
                         s_c, Pij, v_c)                           # intra numerator
        den = jnp.einsum("bhij,bijh->bih", s_c, Pij)
        w_inter = jnp.exp(b_c + m[:, None] - m_i)                 # (B,L,H)
        num = num + w_inter[..., None] * jnp.einsum("bhvk,blhk->blhv", C, q_c)
        den = den + w_inter * jnp.einsum("bhk,blhk->blh", n, q_c)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # Advance the carry.
        m_new = jnp.maximum(bt_c + m, ms_c)                       # (B,H)
        wS = jnp.exp(ws_c - m_new[:, None])                       # (B,L,H)
        C_new = jnp.exp(bt_c + m - m_new)[..., None, None] * C + \
            jnp.einsum("blh,blhv,blhk->bhvk", wS, v_c, k_c)
        n_new = jnp.exp(bt_c + m - m_new)[..., None] * n + \
            jnp.einsum("blh,blhk->bhk", wS, k_c)
        return (C_new, n_new, m_new), h

    if init is None:
        init = (jnp.zeros((B, H, P, P), f32), jnp.zeros((B, H, P), f32),
                jnp.full((B, H), 0.0, f32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (qs, ks, vs, b, D, m_intra, s, btot, wstate, m_state))
    with jax.named_scope("kscope_mlstm"):
        final, hs = jax.lax.scan(step, init, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, P)
    return h, final


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, P = _head_dims(cfg)
    H = cfg.n_heads
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype_of(cfg.compute_dtype)),
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_block(params, x, cfg: ModelConfig, cache: Optional[Dict] = None):
    """x: (B,S,d) pre-normed → (out, new_cache)."""
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    d_inner, P = _head_dims(cfg)
    H = cfg.n_heads
    up = jnp.einsum("bsd,dk->bsk", x.astype(cd), params["up_proj"]["w"].astype(cd))
    up = constrain(up, ("dp", None, "tp"))
    xm, z = jnp.split(up, 2, axis=-1)
    conv_out, conv_state = causal_conv(
        xm, params["conv_w"].astype(cd), params["conv_b"].astype(cd),
        None if cache is None else cache["conv"])
    xc = jax.nn.silu(conv_out)
    q = apply_blockdiag(params["wq"], xc, cd).reshape(B, S, H, P)
    k = apply_blockdiag(params["wk"], xc, cd).reshape(B, S, H, P)
    v = apply_blockdiag(params["wv"], xm, cd).reshape(B, S, H, P)
    gates = jnp.einsum("bsk,kj->bsj", xm.astype(jnp.float32), params["w_gates"]["w"])
    igate, fgate = jnp.split(gates, 2, axis=-1)

    init = None if cache is None else (cache["C"], cache["n"], cache["m"])
    chunk = min(cfg.mlstm_chunk, S)
    if S > 1 and S % chunk == 0:
        h, (C, n, m) = mlstm_chunked(q, k, v, igate, fgate, chunk, init)
    else:
        h, (C, n, m) = mlstm_recurrence(q, k, v, igate, fgate, init)
    h = h.reshape(B, S, d_inner).astype(cd)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", h, params["down_proj"]["w"].astype(cd))
    new_cache = None if cache is None else {"conv": conv_state, "C": C, "n": n, "m": m}
    return out, new_cache


# =============================================================== sLSTM ====
def init_slstm(key, cfg: ModelConfig, dtype) -> Dict:
    d, (d_inner, P) = cfg.d_model, _slstm_dims(cfg)
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    # Input weights for 4 gates (z,i,f,o) + block-diag recurrent weights.
    r = (jax.random.normal(ks[1], (4, H, P, P)) * P ** -0.5).astype(jnp.float32)
    ff = int(d_inner * 4 / 3)
    return {
        "in_proj": init_linear(ks[0], d, d_inner, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, d_inner)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_gates": init_linear(ks[3], d_inner, 4 * d_inner, jnp.float32),
        "r_gates": r,
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_up": init_linear(ks[4], d_inner, 2 * ff, dtype),
        "w_down": init_linear(ks[5], ff, d, dtype, scale=ff ** -0.5),
    }


def make_slstm_step(r):
    """One sLSTM time step.  ``r``: (4,H,P,P) block-diagonal recurrent
    weights for (z,i,f,o).  Carry: (c,n,h,m) each (B,H,P); input gx:
    (B,4,H,P) — this step's input-weight contributions to the gates."""

    def step(carry, gx):
        c, n, h, m = carry
        rec = jnp.einsum("ghpq,bhq->gbhp", r, h)   # (4,B,H,P)
        zt = jnp.tanh(gx[:, 0] + rec[0])
        lit = gx[:, 1] + rec[1]                    # log input gate (i = exp)
        lft = jax.nn.log_sigmoid(gx[:, 2] + rec[2])
        ot = jax.nn.sigmoid(gx[:, 3] + rec[3])
        m_new = jnp.maximum(lft + m, lit)
        ip = jnp.exp(lit - m_new)
        fp = jnp.exp(lft + m - m_new)
        c_new = fp * c + ip * zt
        n_new = fp * n + ip
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    return step


def slstm_block(params, x, cfg: ModelConfig, cache: Optional[Dict] = None):
    cd = dtype_of(cfg.compute_dtype)
    B, S, _ = x.shape
    d_inner, P = _slstm_dims(cfg)
    H = cfg.n_heads
    xi = jnp.einsum("bsd,dk->bsk", x.astype(cd), params["in_proj"]["w"].astype(cd))
    conv_out, conv_state = causal_conv(
        xi, params["conv_w"].astype(cd), params["conv_b"].astype(cd),
        None if cache is None else cache["conv"])
    xc = jax.nn.silu(conv_out)
    gx = jnp.einsum("bsk,kj->bsj", xc.astype(jnp.float32), params["w_gates"]["w"])
    gx = gx.reshape(B, S, 4, H, P)

    step = make_slstm_step(params["r_gates"])
    if cache is None:
        f32 = jnp.float32
        init = tuple(jnp.zeros((B, H, P), f32) for _ in range(4))
    else:
        init = (cache["c"], cache["n"], cache["h"], cache["m"])
    final, hs = jax.lax.scan(step, init, jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_inner).astype(cd)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    up = jnp.einsum("bsk,kj->bsj", h, params["w_up"]["w"].astype(cd))
    a, b = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a) * b, params["w_down"]["w"].astype(cd))
    new_cache = None if cache is None else {
        "conv": conv_state, "c": final[0], "n": final[1], "h": final[2], "m": final[3]}
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, P = _slstm_dims(cfg)
    H = cfg.n_heads
    f32 = jnp.float32
    vec = lambda: jnp.zeros((batch, H, P), f32)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype_of(cfg.compute_dtype)),
        "c": vec(), "n": vec(), "h": vec(), "m": vec(),
    }
