"""Training driver with checkpoint/restart: kill it mid-run and re-invoke —
it resumes from the latest committed checkpoint on identical data.

    PYTHONPATH=src python examples/train_lm.py --steps 60 \
        --ckpt-dir /tmp/repro_ckpt [--model-size 100m]

``--model-size 100m`` builds a ~100M-param granite-family config (a few
hundred steps is a real soak on TPU; on the CPU container keep steps small
or use the default tiny config).
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.models import reduced
from repro.train.trainer import TrainerConfig, make_synthetic_trainer


def build_cfg(size: str):
    base = get_config("granite-3-2b")
    if size == "tiny":
        return reduced(base, vocab_size=512)
    if size == "100m":
        return dataclasses.replace(
            base, name="granite-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab_size=32_000,
            param_dtype="float32", compute_dtype="float32")
    raise SystemExit(f"unknown --model-size {size}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--model-size", default="tiny", choices=["tiny", "100m"])
    args = ap.parse_args()

    cfg = build_cfg(args.model_size)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params → {args.steps} steps")
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=20, log_every=5,
                         ckpt_dir=args.ckpt_dir)
    trainer = make_synthetic_trainer(cfg, tcfg, global_batch=args.batch,
                                     seq_len=args.seq)
    trainer.run()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
