"""Quickstart: train a tiny LM for 30 steps on synthetic data (CPU, ~1 min).

    PYTHONPATH=src python examples/quickstart.py [--arch granite-3-2b]
"""

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.models import reduced
from repro.train.trainer import TrainerConfig, make_synthetic_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), vocab_size=256)
    print(f"arch={args.arch} (reduced: {cfg.param_count()/1e6:.2f}M params)")
    tcfg = TrainerConfig(steps=args.steps, log_every=5)
    trainer = make_synthetic_trainer(cfg, tcfg, global_batch=8, seq_len=64)
    trainer.run()
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    print(f"loss: {first:.3f} → {last:.3f}  ({'✓ learning' if last < first else '✗'})")


if __name__ == "__main__":
    main()
