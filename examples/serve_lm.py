"""End-to-end serving driver: briefly train a small LM so it has structure,
then serve a stream of batched requests through the continuous-batching
engine and report latency/throughput.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen1.5-0.5b]
                                               [--requests 24] [--slots 4]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import reduced
from repro.serve import Request, ServeEngine
from repro.train.trainer import TrainerConfig, make_synthetic_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), vocab_size=256)
    print(f"arch={args.arch} reduced {cfg.param_count()/1e6:.2f}M params")
    tcfg = TrainerConfig(steps=args.train_steps, log_every=100)
    trainer = make_synthetic_trainer(cfg, tcfg, global_batch=8, seq_len=64)
    state = trainer.run()
    params = state["params"]

    rng = np.random.default_rng(0)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=64, eos_id=-1, temperature=0.0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        engine.submit(Request(i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = engine.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)}/{args.requests} requests, {toks} tokens "
          f"in {dt:.2f}s → {toks/dt:.1f} tok/s "
          f"({engine.steps} engine steps, {args.slots} slots)")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
