"""The paper's contribution, end to end, on a TPU fleet:

  1. build a heterogeneous pod fleet (different $/chip-hour),
  2. admit a stream of training/serving jobs FCFS under SLO/budget bounds
     (Step 5 — first-come-first-served fills the cheap pods),
  3. run the in-operation reconfiguration (Step 7): the LP trial-solve
     finds a placement with higher group satisfaction and emits migrations,
  4. EXECUTE one migration for a real (tiny) training job through the
     elastic bridge (`fleet.elastic_bridge`): snapshot → reshard →
     resume with per-phase timings — the framework's live migration,
  5. report the satisfaction ratios (the paper's fig. 5(b) quantity).

    PYTHONPATH=src python examples/reconfiguration_demo.py
"""

import tempfile

import numpy as np

from repro.configs import get_config
from repro.core.cluster import FleetScheduler, JobSpec, PodSpec, build_fleet_topology
from repro.fleet.elastic_bridge import LiveElasticBackend, execute_move
from repro.models import reduced
from repro.runtime.elastic import MeshPlan
from repro.train import make_optimizer
from repro.train.trainer import TrainerConfig, make_synthetic_trainer


def main():
    # ---- 1. fleet ----
    pods = [PodSpec("tokyo-a", 256, 1.2), PodSpec("tokyo-b", 256, 1.2),
            PodSpec("osaka-spot", 256, 0.85), PodSpec("osaka-v5p", 256, 2.1)]
    topo = build_fleet_topology(pods)
    sched = FleetScheduler(topo, reconfig_every=10 ** 9, window=24)  # manual Step 7
    print("fleet:", ", ".join(f"{p.name}(${p.chip_hour_usd}/chip·h)" for p in pods))

    # ---- 2. FCFS admission ----
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(14):
        fast = i % 3 == 0
        t = float(rng.uniform(0.8, 2.0))
        jobs.append(JobSpec(
            job_id=i, arch="granite-3-2b", shape="train_4k", chips=64,
            step_time_s=t,
            step_slo_s=t + (0.1 if fast else 2.0),
            budget_usd_month=None if fast else 90_000.0,
        ))
    for j in jobs:
        pod = sched.submit(j)
        print(f"  job {j.job_id:2d} (slo={j.step_slo_s:.2f}s"
              f"{', budget' if j.budget_usd_month else ''}) → {pod}")
    print("utilization:", {k: f"{v:.0%}" for k, v in sched.utilization().items()})

    # Two early jobs on the cheap pod complete and release their slices —
    # the first-come-first-served skew the paper targets: later (budget)
    # jobs are stuck on expensive pods while cheap capacity is now free.
    for done in (1, 2):
        sched.engine.release(done)
    print("jobs 1,2 completed → osaka-spot capacity freed")

    # ---- 3. reconfiguration trial (eq. 1) ----
    res = sched.recon.plan(sched.engine.recent(24))
    mmr = res.mean_moved_ratio   # None when the trial moves nothing
    print(f"\nreconfig trial: S {res.s_before:.3f} → {res.s_after:.3f} "
          f"(gain {res.gain:.3f}), {res.n_moved} moves, "
          f"mean X+Y of moved = {f'{mmr:.4f}' if mmr is not None else 'n/a'}")
    for mv in res.moves:
        print(f"  move job {mv.req_id}: {mv.old.node.site_id} → "
              f"{mv.new.node.site_id}  (ratio {mv.ratio:.4f})")
    sched.recon.apply(res)

    # ---- 4. live-migrate one real training job through the bridge ----
    if res.moves:
        mv = res.moves[0]
        req = sched.engine.placed[mv.req_id].request
        print(f"\nexecuting migration of job {mv.req_id} as ckpt→reshard→resume:")
        cfg = reduced(get_config("granite-3-2b"), vocab_size=128)
        opt = make_optimizer("adamw", lr=1e-3)
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainerConfig(steps=6, log_every=2, ckpt_dir=d, ckpt_every=100)
            trainer = make_synthetic_trainer(cfg, tcfg, global_batch=4, seq_len=32)
            state = trainer.run()
            # The elastic bridge runs the same pipeline the fleet runtime
            # simulates: snapshot (ckpt.save), transfer (priced over the
            # move's links), restore (MeshPlan rebuild over the
            # destination's devices + reshard_restore).
            backend = LiveElasticBackend()
            backend.register_job(mv.req_id, d, cfg, opt,
                                 MeshPlan((1, 1), ("data", "model")))
            backend.update_state(mv.req_id, state, step=6)   # pause
            phases = execute_move(backend, req, mv)
            resumed = backend.resumed[mv.req_id]
            print(f"  phases: snapshot {phases.snapshot_s:.3f}s + "
                  f"transfer {phases.transfer_s:.3f}s ({phases.mbits:.0f} Mb) + "
                  f"restore {phases.restore_s:.3f}s "
                  f"→ downtime {phases.downtime_s:.3f}s")
            print(f"  restored at step {resumed.step} on "
                  f"{mv.new.node.site_id} (mesh {resumed.plan.shape}); resuming")
            tcfg2 = TrainerConfig(steps=10, log_every=2)
            trainer2 = make_synthetic_trainer(cfg, tcfg2, global_batch=4, seq_len=32)
            trainer2.run(state=resumed.state, start_step=resumed.step)
        print("  migration complete — no training progress lost")

    # ---- 5. the paper's metric ----
    sat = [s.ratio for s in res.satisfaction if s.ratio < 2.0 - 1e-9]
    print(f"\nimproved jobs: {len(sat)}; mean X+Y = "
          f"{np.mean(sat) if sat else 2.0:.4f}  (paper fig.5(b): ≈1.96 regime)")


if __name__ == "__main__":
    main()
