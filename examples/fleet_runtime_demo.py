"""Continuous-operation fleet runtime, end to end:

  1. compile a scenario (event schedule over a topology) — default is the
     flash-crowd-during-reconfig story: a forced reconfiguration's
     migrations are still copying state when a flash crowd lands and a
     node fails, aborting the transfers headed to it;
  2. drive it through the discrete-event runtime under three policies —
     the paper's MILP, the decomposed planner (fleet.planner) and a
     no-op control — and
  3. print the per-tick telemetry so the adaptation is visible: moved
     apps, satisfaction of moved apps (fig. 5(b) quantity, raw and
     traffic-weighted), transfers started / in flight, utilization —
     plus the migration ledger (durations, aborts, downtime).

    PYTHONPATH=src python examples/fleet_runtime_demo.py [scenario]
"""

import sys

from repro.fleet import SCENARIOS, build_scenario, get_policy


def run_one(name: str, policy_name: str, seed: int = 0):
    spec = build_scenario(name, seed=seed)
    runtime = spec.make_runtime(get_policy(policy_name))
    tel = runtime.run(spec.event_queue(), scenario=name, seed=seed)
    return tel


def _r(v, fmt="9.4f"):
    width = int(fmt.split(".")[0])
    return f"{v:{fmt}}" if v is not None else "--".rjust(width)


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "flash-crowd-during-reconfig"
    if name not in SCENARIOS:
        raise SystemExit(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")

    print(f"scenario: {name}\n")
    for policy in ("milp", "decomposed", "noop"):
        tel = run_one(name, policy)
        c = tel.counters
        print(f"--- policy = {policy} ---")
        print(f"{'t':>9} {'trigger':>9} {'alive':>5} {'moved':>5} "
              f"{'X+Y moved':>9} {'X+Y wtd':>9} {'start':>5} {'infl':>4} "
              f"{'rate':>5} {'util':>5}")
        for t in tel.ticks:
            print(f"{t.t:9.0f} {t.trigger:>9} {t.n_alive:5d} {t.n_moved:5d} "
                  f"{_r(t.mean_moved_ratio)} {_r(t.mean_moved_ratio_weighted)} "
                  f"{t.n_started:5d} {t.n_inflight:4d} "
                  f"{t.mean_rate:5.2f} {t.utilization:5.2f}")
        n_ab = sum(1 for m in tel.migrations if m.outcome == "aborted")
        print(f"totals: {c['arrivals']} arrivals ({c['arrivals_inflight']} during "
              f"in-flight migrations), {c['admitted']} admitted, "
              f"{c['rejected']} rejected, {c['departures']} departed, "
              f"{c['failover_moved']} failed over, {c['moves']} moves planned")
        print(f"ledger: {c['migrations_started']} transfers started, "
              f"{c['migrations_completed']} completed, {n_ab} aborted, "
              f"{c['migrations_cancelled']} cancelled; "
              f"total downtime {tel.total_downtime_s:.1f}s")
        mmr = tel.mean_moved_ratio
        print(f"mean moved-app satisfaction X+Y = "
              f"{mmr if mmr is None else round(mmr, 4)} "
              f"(2.0 = unchanged; paper fig. 5(b) ≈ 1.96)\n")


if __name__ == "__main__":
    main()
