"""Continuous-operation fleet runtime, end to end:

  1. compile a scenario (event schedule over a topology) — here the
     node-outage story: steady paper workload, then cloud GPUs fail
     mid-run and recover later;
  2. drive it through the discrete-event runtime under two policies —
     the paper's MILP vs a no-op control — and
  3. print the per-tick telemetry so the adaptation is visible: moved
     apps, satisfaction of moved apps (fig. 5(b) quantity), migration
     makespan with link-overlap, utilization.

    PYTHONPATH=src python examples/fleet_runtime_demo.py [scenario]
"""

import sys

from repro.fleet import SCENARIOS, build_scenario, get_policy


def run_one(name: str, policy_name: str, seed: int = 0):
    spec = build_scenario(name, seed=seed)
    runtime = spec.make_runtime(get_policy(policy_name))
    tel = runtime.run(spec.event_queue(), scenario=name, seed=seed)
    return tel


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "node-outage"
    if name not in SCENARIOS:
        raise SystemExit(f"unknown scenario {name!r}; have {sorted(SCENARIOS)}")

    print(f"scenario: {name}\n")
    for policy in ("milp", "noop"):
        tel = run_one(name, policy)
        c = tel.counters
        print(f"--- policy = {policy} ---")
        print(f"{'t':>9} {'trigger':>9} {'alive':>5} {'moved':>5} "
              f"{'X+Y moved':>9} {'mksp s':>7} {'ovlp':>5} {'util':>5}")
        for t in tel.ticks:
            print(f"{t.t:9.0f} {t.trigger:>9} {t.n_alive:5d} {t.n_moved:5d} "
                  f"{t.mean_moved_ratio:9.4f} {t.migration_makespan_s:7.1f} "
                  f"{t.migration_overlap:5.2f} {t.utilization:5.2f}")
        print(f"totals: {c['arrivals']} arrivals, {c['admitted']} admitted, "
              f"{c['rejected']} rejected, {c['departures']} departed, "
              f"{c['failover_moved']} failed over, {c['moves']} moved")
        print(f"mean moved-app satisfaction X+Y = {tel.mean_moved_ratio:.4f} "
              f"(2.0 = unchanged; paper fig. 5(b) ≈ 1.96)\n")


if __name__ == "__main__":
    main()
