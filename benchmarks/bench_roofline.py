"""Roofline benchmark: reads the dry-run result JSONs and emits the
§Roofline table rows (one per arch × shape × mesh)."""

from __future__ import annotations

import json
import os
from typing import List

RESULTS = (("baseline", "results/dryrun_single.json"),
           ("multipod", "results/dryrun_multi.json"),
           ("optimized", "results/dryrun_optimized.json"))


def run() -> List[str]:
    rows: List[str] = []
    for tag, path in RESULTS:
        if not os.path.exists(path):
            continue
        for r in json.load(open(path)):
            if r["status"] == "skipped":
                rows.append(f"roofline,{tag},{r['arch']},{r['shape']},{r['mesh']},skipped")
                continue
            if r["status"] != "ok":
                rows.append(f"roofline,{tag},{r['arch']},{r['shape']},{r['mesh']},FAILED")
                continue
            rf = r["roofline"]
            rows.append(
                f"roofline,{tag},{r['arch']},{r['shape']},{r['mesh']},"
                f"tC={rf['t_compute_s']:.4f},tM={rf['t_memory_s']:.4f},"
                f"tMpallas={rf.get('t_memory_pallas_s', float('nan')):.4f},"
                f"tNet={rf['t_collective_s']:.4f},bneck={rf['bottleneck']},"
                f"useful={rf['useful_flops_ratio']:.3f},"
                f"mfu={rf['mfu_roofline']:.4f}"
            )
    if not rows:
        rows.append("roofline,NO_RESULTS,run `python -m repro.launch.dryrun --all`")
    return rows
