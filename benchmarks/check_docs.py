"""Docs-consistency check: fail when a ``docs/*.md`` page references a
code symbol that no longer exists in the tree.

Heuristic by design (a grep, not an import): the documentation quotes
symbols in backtick spans.  Every span is mined for *symbol-looking*
tokens — identifiers with an underscore, CamelCase names, ``calls()``, and
dotted paths — and each token must appear as an identifier somewhere in
``src/``, ``tests/``, ``benchmarks/`` or ``examples/`` (or be a real file
path).  Plain-English backticked words without symbol shape are ignored,
so prose like `window` or `milp` never false-positives, while a renamed
`reshard_restore` or deleted `MigrationExecutor` breaks the build the
moment a doc still mentions it.

    PYTHONPATH=src python benchmarks/check_docs.py [docs ...]

Exit status: 0 = docs consistent, 1 = stale references found (the count
is printed; it is NOT the exit code — codes wrap modulo 256).
"""

from __future__ import annotations

import os
import re
import sys
from typing import Dict, Iterable, List, Set, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CODE_DIRS = ("src", "tests", "benchmarks", "examples")

#: Pages that must exist — auto-discovery alone would silently pass if a
#: subsystem page were deleted along with its stale references.
REQUIRED_DOCS = ("architecture.md", "elastic.md", "fleet.md",
                 "observability.md", "planner.md")

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")
_CAMEL = re.compile(r"^[A-Z][a-z0-9]+[A-Z]")         # e.g. MeshPlan
_SPAN = re.compile(r"`([^`\n]+)`")


def _code_identifiers() -> Set[str]:
    """Every identifier token in the code tree, plus file/dir basenames
    (so `elastic_bridge` resolves via elastic_bridge.py)."""
    idents: Set[str] = set()
    for top in CODE_DIRS:
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, top)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for name in filenames:
                stem, ext = os.path.splitext(name)
                idents.update(_IDENT.findall(stem))
                if ext != ".py":
                    continue
                with open(os.path.join(dirpath, name), errors="replace") as f:
                    idents.update(_IDENT.findall(f.read()))
            idents.update(_IDENT.findall(os.path.basename(dirpath)))
    return idents


def _symbol_tokens(span: str) -> Iterable[str]:
    """Symbol-looking tokens inside one backtick span."""
    if not span.isascii():
        return []          # math/prose spans (Σ w_k·(X_k + Y_k), arrows …)
    out: List[str] = []
    for tok in _IDENT.findall(span):
        looks_symbol = (
            "_" in tok
            or _CAMEL.match(tok)
            or f"{tok}(" in span       # quoted call: plan(), observe(now=…)
            or f"{tok}." in span or f".{tok}" in span   # dotted path part
        )
        if looks_symbol:
            out.append(tok)
    return out


def _is_real_path(span: str) -> bool:
    return ("/" in span or span.endswith((".py", ".md", ".json"))) and (
        os.path.exists(os.path.join(ROOT, span))
        or os.path.exists(os.path.join(ROOT, "docs", span)))


def check(doc_paths: Iterable[str]) -> List[Tuple[str, int, str, str]]:
    """Returns (doc, line, span, missing-token) for every stale reference."""
    idents = _code_identifiers()
    stale: List[Tuple[str, int, str, str]] = []
    for doc in doc_paths:
        with open(doc) as f:
            for lineno, line in enumerate(f, 1):
                for span in _SPAN.findall(line):
                    if _is_real_path(span):
                        continue
                    for tok in _symbol_tokens(span):
                        if tok not in idents:
                            stale.append((os.path.relpath(doc, ROOT),
                                          lineno, span, tok))
    return stale


def main(argv: List[str]) -> int:
    docs = argv or sorted(
        os.path.join(ROOT, "docs", n)
        for n in os.listdir(os.path.join(ROOT, "docs")) if n.endswith(".md"))
    if not argv:
        missing = [n for n in REQUIRED_DOCS
                   if not os.path.exists(os.path.join(ROOT, "docs", n))]
        if missing:
            print(f"required docs missing: {', '.join(missing)}")
            return 1
    stale = check(docs)
    for doc, lineno, span, tok in stale:
        print(f"{doc}:{lineno}: `{span}` references unknown symbol '{tok}'")
    if stale:
        print(f"{len(stale)} stale reference(s) across {len(docs)} pages")
        return 1
    print(f"docs consistent: {len(docs)} pages, 0 stale references")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
