"""Paper-table benchmarks: fig. 5(a) actual-reconfiguration counts,
fig. 5(b) satisfaction ratios, and the solver-time claims (§4.2)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (
    PlacementEngine,
    Reconfigurator,
    build_paper_topology,
    run_paper_experiment,
    sample_requests,
)


def bench_fig5(seeds=(0, 1, 2)) -> List[str]:
    """Rows: window size → (moved count, moved %, mean X+Y ratio).
    Paper: ~10 % moved; ratio ≈ 1.96, insensitive to window size."""
    rows = []
    for window in (100, 200, 400):
        moved, frac, ratio, times = [], [], [], []
        for s in seeds:
            r = run_paper_experiment(window, seed=s)
            e = r.events[0]
            moved.append(e.n_moved)
            frac.append(e.n_moved / e.n_target)
            if e.mean_moved_ratio is not None:   # None when nothing moved
                ratio.append(e.mean_moved_ratio)
            times.append(e.plan_time_s)
        mean_ratio = f"{np.mean(ratio):.4f}" if ratio else "nan"
        rows.append(
            f"fig5,window={window},moved={np.mean(moved):.1f},"
            f"moved_frac={np.mean(frac):.3f},mean_ratio={mean_ratio},"
            f"solver_s={np.mean(times):.3f}"
        )
    return rows


def bench_solver_scaling(seeds=(0,)) -> List[str]:
    """Paper §4.2: new placement of 500 apps < 1 min; reconfiguration < 10 s
    at 100 apps, < 1 min at 400.  Ours (HiGHS on the same formulation)."""
    rows = []
    for seed in seeds:
        topo = build_paper_topology()
        rng = np.random.default_rng(seed)
        engine = PlacementEngine(topo)
        reqs = sample_requests(topo, 500, rng)
        t0 = time.perf_counter()
        for r in reqs:
            engine.place(r)
        t_place = time.perf_counter() - t0
        rows.append(f"placement_500,seed={seed},s={t_place:.3f},paper_budget_s=60")
        recon = Reconfigurator(engine)
        for n in (100, 200, 400):
            res = recon.plan(engine.recent(n))
            budget = 10 if n == 100 else 60
            rows.append(
                f"reconfig_{n},seed={seed},s={res.plan_time_s:.3f},"
                f"paper_budget_s={budget},moved={res.n_moved}"
            )
    return rows


def bench_backend_compare() -> List[str]:
    """Own branch-and-bound vs HiGHS on the 100-app reconfiguration."""
    rows = []
    topo = build_paper_topology()
    rng = np.random.default_rng(0)
    engine = PlacementEngine(topo)
    for r in sample_requests(topo, 200, rng):
        engine.place(r)
    for backend in ("highs", "bnb"):
        recon = Reconfigurator(engine, backend=backend, time_limit_s=120)
        t0 = time.perf_counter()
        res = recon.plan(engine.recent(60))
        rows.append(f"backend_{backend},s={time.perf_counter()-t0:.3f},"
                    f"gain={res.gain:.4f},moved={res.n_moved}")
    return rows


def run() -> List[str]:
    return bench_fig5() + bench_solver_scaling() + bench_backend_compare()
