"""Fleet-runtime benchmark: scenario × policy sweep of the continuous-
operation simulator (`repro.fleet`).

Each cell runs one scenario (paper-steady-state, diurnal, flash-crowd,
node-outage, hetero-expansion) under one reconfiguration policy (the
paper's MILP vs greedy / hillclimb / GA) and reports the paper's fig. 5
quantities as time series aggregates: moved ratio, mean moved-app
satisfaction X+Y, solver latency, plus migration makespan/overlap.

``run()`` prints the CSV rows for `benchmarks.run`; ``sweep()`` returns
machine-readable dict rows for ``benchmarks.run --json`` → BENCH_fleet.json.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

DEFAULT_POLICIES = ("milp", "greedy", "hillclimb", "ga")


def sweep(
    scenarios: Optional[Sequence[str]] = None,
    policies: Sequence[str] = DEFAULT_POLICIES,
    seed: int = 0,
    with_ticks: bool = True,
) -> List[Dict]:
    """One row per (scenario, policy) cell."""
    from repro.fleet import SCENARIOS, build_scenario, get_policy

    rows: List[Dict] = []
    for sc in scenarios or sorted(SCENARIOS):
        for pol in policies:
            spec = build_scenario(sc, seed=seed)
            runtime = spec.make_runtime(get_policy(pol))
            t0 = time.perf_counter()
            tel = runtime.run(spec.event_queue(), scenario=sc, seed=seed)
            wall = time.perf_counter() - t0
            d = tel.to_dict()
            # Overlap averaged over ticks that actually migrated; idle ticks
            # would dilute the link-parallelism statistic.
            migrated = [t for t in d["ticks"] if t["migration_makespan_s"] > 0]
            overlap = (sum(t["migration_overlap"] for t in migrated)
                       / len(migrated)) if migrated else 0.0
            row = {
                "scenario": sc,
                "policy": pol,
                "seed": seed,
                "wall_s": round(wall, 3),
                "fingerprint": tel.fingerprint(),
                **d["counters"],
                **d["summary"],
                "mean_migration_makespan_s": round(
                    sum(t["migration_makespan_s"] for t in d["ticks"])
                    / max(len(d["ticks"]), 1), 6),
                "mean_migration_overlap": round(overlap, 6),
            }
            if with_ticks:
                row["ticks_series"] = d["ticks"]
            rows.append(row)
    return rows


def run(seed: int = 0) -> List[str]:
    """CSV rows for the default `benchmarks.run` text mode."""
    out: List[str] = []
    for r in sweep(seed=seed, with_ticks=False):
        out.append(
            f"fleet_{r['scenario']},policy={r['policy']},"
            f"arrivals={r['arrivals']},admitted={r['admitted']},"
            f"rejected={r['rejected']},moves={r['moves']},"
            f"mean_ratio={r['mean_moved_ratio']:.4f},"
            f"gain={r['total_gain']:.3f},"
            f"solver_s={r['mean_solver_time_s']:.4f},"
            f"makespan_s={r['mean_migration_makespan_s']:.2f},"
            f"overlap={r['mean_migration_overlap']:.2f},"
            f"wall_s={r['wall_s']:.2f}"
        )
    return out
