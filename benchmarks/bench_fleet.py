"""Fleet-runtime benchmark: scenario × policy sweep of the continuous-
operation simulator (`repro.fleet`).

Each cell runs one scenario (paper-steady-state, diurnal-streams,
flash-crowd[-during-reconfig], node/site-outage, flapping-node,
hetero-expansion) under one reconfiguration policy (the paper's MILP vs
greedy / hillclimb / GA / adaptive) and reports the paper's fig. 5
quantities as time-series aggregates: moved ratio, mean moved-app
satisfaction X+Y (raw and traffic-weighted), solver latency, plus the
time-extended migration accounting (started / completed / aborted
transfers, mean transfer duration, total downtime, in-flight collisions).

``run()`` prints the CSV rows for `benchmarks.run`; ``sweep()`` returns
machine-readable dict rows for ``benchmarks.run --json`` → BENCH_fleet.json.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

DEFAULT_POLICIES = ("milp", "greedy", "hillclimb", "ga", "adaptive")


def _cell(sc: str, pol: str, seed: int, with_ticks: bool,
          scenario_kwargs: Optional[Dict] = None) -> Dict:
    from repro.fleet import build_scenario, get_policy

    spec = build_scenario(sc, seed=seed, **(scenario_kwargs or {}))
    runtime = spec.make_runtime(get_policy(pol))
    t0 = time.perf_counter()
    tel = runtime.run(spec.event_queue(), scenario=sc, seed=seed)
    wall = time.perf_counter() - t0
    d = tel.to_dict()
    row = {
        "scenario": sc,
        "policy": pol,
        "seed": seed,
        "wall_s": round(wall, 3),
        "fingerprint": tel.fingerprint(),
        **d["counters"],
        **d["summary"],
    }
    if with_ticks:
        row["ticks_series"] = d["ticks"]
        row["migrations_series"] = d["migrations"]
    return row


def sweep(
    scenarios: Optional[Sequence[str]] = None,
    policies: Sequence[str] = DEFAULT_POLICIES,
    seed: int = 0,
    with_ticks: bool = True,
) -> List[Dict]:
    """One row per (scenario, policy) cell."""
    from repro.fleet import SCENARIOS

    rows: List[Dict] = []
    for sc in scenarios or sorted(SCENARIOS):
        for pol in policies:
            rows.append(_cell(sc, pol, seed, with_ticks))
    return rows


def smoke(seed: int = 0) -> List[Dict]:
    """CI sanity slice: two fast cells with every moving part exercised
    (request streams, in-flight migrations, adaptive switching)."""
    return [
        _cell("paper-steady-state", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 250}),
        _cell("diurnal-streams", "adaptive", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 200}),
    ]


def _fmt_ratio(v) -> str:
    return f"{v:.4f}" if v is not None else "nan"


def run(seed: int = 0) -> List[str]:
    """CSV rows for the default `benchmarks.run` text mode."""
    out: List[str] = []
    for r in sweep(seed=seed, with_ticks=False):
        out.append(
            f"fleet_{r['scenario']},policy={r['policy']},"
            f"arrivals={r['arrivals']},admitted={r['admitted']},"
            f"rejected={r['rejected']},moves={r['moves']},"
            f"mean_ratio={_fmt_ratio(r['mean_moved_ratio'])},"
            f"mean_ratio_w={_fmt_ratio(r['mean_moved_ratio_weighted'])},"
            f"gain={r['total_gain']:.3f},"
            f"solver_s={r['mean_solver_time_s']:.4f},"
            f"migrations={r['migrations_completed']}/{r['migrations_started']},"
            f"aborted={r['migrations_aborted']},"
            f"mig_dur_s={_fmt_ratio(r['mean_migration_duration_s'])},"
            f"downtime_s={r['total_downtime_s']:.1f},"
            f"arr_inflight={r['arrivals_inflight']},"
            f"wall_s={r['wall_s']:.2f}"
        )
    return out
