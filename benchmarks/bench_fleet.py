"""Fleet-runtime benchmark: scenario × policy × scale sweep of the
continuous-operation simulator (`repro.fleet`).

Each cell runs one scenario (paper-steady-state, diurnal-streams,
flash-crowd[-during-reconfig], node/site-outage, backbone-cut,
flapping-node, hetero-expansion) under one reconfiguration policy (the
paper's MILP vs greedy / hillclimb / GA / adaptive, plus the planner
subsystem's decomposed and rolling-horizon policies) and reports the
paper's fig. 5 quantities as time-series aggregates: moved ratio, mean
moved-app satisfaction X+Y (raw and traffic-weighted), solver latency,
the time-extended migration accounting (started / completed / aborted
transfers, durations, downtime, collisions), the elastic-bridge phase
totals (snapshot / transfer / restore seconds per run, per-migration in
``migrations_series``) and the planner detail (regions solved, boundary
crossings, per-region solve latency).

``scale_sweep()`` grows the paper topology ×2/×4/×8 with window
400×scale (the ROADMAP window sweep) — the rows record where the
monolithic MILP's tick latency climbs over the adaptive solver budget
while the decomposed planner's stays flat (the solver-latency cliff).
The driver extends it with a ×32 planetary slice (incremental /
hierarchical / greedy only) and ``planetary_rows()`` pushes the
steady-tick microbench to ×64/×256 fleets with a >100k-app window under
the hierarchical planner.

``run()`` prints the CSV rows for `benchmarks.run`; ``sweep()`` returns
machine-readable dict rows for ``benchmarks.run --json`` → BENCH_fleet.json.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

DEFAULT_POLICIES = ("milp", "greedy", "hillclimb", "ga", "adaptive",
                    "decomposed", "incremental", "hierarchical", "horizon")

#: The cliff sweep: cheaper policy set (no GA — its cost is orthogonal to
#: topology scale) over the scenarios that exercise steady churn and the
#: new link-cut path.  ``incremental`` rides next to ``decomposed`` so the
#: rows expose the incremental-vs-full planning latency column directly.
SCALE_SWEEP_SCALES = (2, 4, 8)
SCALE_SWEEP_POLICIES = ("milp", "decomposed", "incremental", "horizon",
                        "adaptive", "greedy")


def _cell(sc: str, pol: str, seed: int, with_ticks: bool,
          scenario_kwargs: Optional[Dict] = None,
          backend=None, slo=None, policy_kwargs: Optional[Dict] = None,
          config_kwargs: Optional[Dict] = None) -> Dict:
    """``backend`` overrides the scenario's elastic-bridge backend
    (`RuntimeConfig.elastic_backend`); None keeps the default simulated
    backend.  The row records which backend executed the migrations.
    ``slo`` overrides the runtime's `SloConfig` (for cells that provoke
    burn-rate breaches); ``policy_kwargs`` are forwarded to `get_policy`;
    ``config_kwargs`` set `RuntimeConfig` fields (e.g. ``cost_feedback``)."""
    from repro.fleet import build_scenario, get_policy

    kwargs = dict(scenario_kwargs or {})
    spec = build_scenario(sc, seed=seed, **kwargs)
    if backend is not None:
        spec.config.elastic_backend = backend
    if slo is not None:
        spec.config.slo = slo
    for k, v in (config_kwargs or {}).items():
        setattr(spec.config, k, v)
    runtime = spec.make_runtime(get_policy(pol, **(policy_kwargs or {})))
    t0 = time.perf_counter()
    tel = runtime.run(spec.event_queue(), scenario=sc, seed=seed)
    wall = time.perf_counter() - t0
    d = tel.to_dict()
    ticks = tel.ticks
    row = {
        "scenario": sc,
        "policy": pol,
        "seed": seed,
        "scale": kwargs.get("scale", 1),
        "backend": runtime.executor.backend.name,
        "wall_s": round(wall, 3),
        "fingerprint": tel.fingerprint(),
        # solver-latency cliff evidence: worst tick vs the adaptive budget
        "max_solver_time_s": round(max((t.solver_time_s for t in ticks),
                                       default=0.0), 6),
        "max_region_solve_s": round(max((t.region_solve_max_s for t in ticks),
                                        default=0.0), 6),
        "boundary_crossings": sum(t.boundary_crossings for t in ticks),
        # incremental-planning telemetry (zero under non-incremental policies)
        "regions_solved": sum(t.n_regions for t in ticks),
        "regions_reused": sum(t.regions_reused for t in ticks),
        "warm_start_hits": sum(t.warm_start_hits for t in ticks),
        **d["counters"],
        **d["summary"],
    }
    # Calibration-ledger columns (repro.fleet.obs.calibration): how many
    # predicted-vs-actual joins landed, how many drift detectors fired.
    calib = d.get("calibration") or {}
    row["cost_feedback"] = bool(spec.config.cost_feedback)
    row["admission_mode"] = spec.config.admission_mode
    row["calib_samples"] = calib.get("samples", 0)
    row["calib_excluded"] = calib.get("excluded", 0)
    row["calib_drifts"] = len(calib.get("drifts", ()))
    if calib.get("strategies"):
        row["calib_strategies"] = calib["strategies"]
    # Serving-workload summary (repro.fleet.serving): token conservation
    # totals, throughput, per-token p99 and completed migrations by
    # strategy.  Absent on non-serving scenarios.
    srv = d.get("serving")
    if srv:
        row["serving"] = srv
    # Deterministic percentile columns from the fixed-bucket metrics
    # registry (repro.fleet.obs): satisfaction quantiles are simulated
    # quantities, solver-latency quantiles are wall-clock profiling.
    met = d["metrics"]
    for col, metric in (("satisfaction", "tick/satisfaction"),
                        ("solver_time_s", "solver/latency_s"),
                        ("mig_downtime_s", "migration/downtime_s"),
                        ("forecast_error", "forecast/error"),
                        ("calib_downtime_err", "calibration/downtime_rel_err")):
        snap = met.get(metric) or {}
        for q in ("p50", "p90", "p99"):
            row[f"{q}_{col}"] = snap.get(q)
    if with_ticks:
        row["ticks_series"] = d["ticks"]
        row["migrations_series"] = d["migrations"]
    return row


def sweep(
    scenarios: Optional[Sequence[str]] = None,
    policies: Sequence[str] = DEFAULT_POLICIES,
    seed: int = 0,
    with_ticks: bool = True,
    scale: int = 1,
) -> List[Dict]:
    """One row per (scenario, policy) cell at one topology scale."""
    from repro.fleet import SCENARIOS

    kwargs = {"scale": scale} if scale != 1 else {}
    rows: List[Dict] = []
    for sc in scenarios or sorted(SCENARIOS):
        for pol in policies:
            rows.append(_cell(sc, pol, seed, with_ticks, kwargs))
    return rows


def scale_sweep(
    scales: Sequence[int] = SCALE_SWEEP_SCALES,
    policies: Sequence[str] = SCALE_SWEEP_POLICIES,
    scenarios: Sequence[str] = ("paper-steady-state", "backbone-cut"),
    seed: int = 0,
    with_ticks: bool = True,
) -> List[Dict]:
    """Scenario × policy × scale rows with the big re-placement windows
    (400×scale on paper-steady-state) that expose the monolithic MILP's
    latency cliff."""
    rows: List[Dict] = []
    for scale in scales:
        for sc in scenarios:
            kwargs: Dict = {"scale": scale}
            if sc == "paper-steady-state":
                kwargs.update(window=400 * scale, reconfig_every=400 * scale)
            for pol in policies:
                rows.append(_cell(sc, pol, seed, with_ticks, kwargs))
    return rows


def steady_tick_rows(scales: Sequence[int] = (2, 4),
                     seed: int = 0, n_ticks: int = 5,
                     policies: Sequence[str] = ("decomposed", "incremental"),
                     apps_factor: int = 625,
                     window_factor: int = 400) -> List[Dict]:
    """Steady-state tick cost microbench: the paper's relocation loop
    re-solves *periodically regardless of churn*, so the cost of a tick in
    a quiet period — no arrivals/departures/drifts since the last plan —
    is a first-class quantity.  The full decomposed planner pays its whole
    solve chain every time; the incremental planner's change journal sees
    no dirty regions and replays every cached plan; the hierarchical
    planner does the same over its region tree (on ≥4000-node fleets).
    One row per (scale, policy) with the first (cold) tick split out and
    a deterministic steady-tick p50; all policies in one cell must agree
    on the plan (the parity assertion)."""
    import statistics

    import numpy as np

    from repro.core import PlacementEngine, build_paper_topology, sample_requests
    from repro.fleet import get_policy

    rows: List[Dict] = []
    for scale in scales:
        topo = build_paper_topology(scale=scale)
        engine = PlacementEngine(topo)
        rng = np.random.default_rng(seed)
        for r in sample_requests(topo, apps_factor * scale, rng):
            engine.place(r)
        window = engine.recent(window_factor * scale)
        weights = {r: 1.0 for r in window}
        base = None
        for pol in policies:
            p = get_policy(pol)
            times, res = [], None
            for _ in range(n_ticks):
                res = p.plan(engine, window, weights=weights)
                times.append(res.plan_time_s)
            stats = p.last_plan_stats
            key = (round(res.s_after, 9),
                   tuple(sorted((m.req_id, m.new.node.node_id)
                                for m in res.moves)))
            if base is None:
                base = key
            assert key == base, "steady-tick parity violated"
            steady = times[1:] or times
            rows.append({
                "benchmark": "steady_tick",
                "scenario": "steady-tick",
                "policy": pol,
                "scale": scale,
                "apps": len(engine.placed),
                "window": len(window),
                "first_tick_s": round(times[0], 6),
                "mean_steady_tick_s": round(sum(steady) / len(steady), 6),
                "p50_steady_tick_s": round(statistics.median(steady), 6),
                "regions_solved_last": stats.n_regions,
                "regions_reused_last": stats.regions_reused,
                "warm_start_hits_last": stats.warm_start_hits,
            })
    return rows


def planetary_rows(seed: int = 0, n_ticks: int = 5) -> List[Dict]:
    """Planetary-scale steady-tick rows: ×64 (incremental vs hierarchical)
    and ×256 under the hierarchical planner only, with the per-scale app
    count tuned so the ×256 window holds >100k apps (440·256 = 112 640
    placements, window 400·256 = 102 400).  These are the fleets the
    region-of-regions tree exists for — the flat policies are left out of
    the ×256 cell by design (one global coordination sweep at that size is
    exactly the cost the hierarchy removes)."""
    rows = steady_tick_rows((64,), seed=seed, n_ticks=n_ticks,
                            policies=("incremental", "hierarchical"),
                            apps_factor=440)
    rows += steady_tick_rows((256,), seed=seed, n_ticks=n_ticks,
                             policies=("hierarchical",), apps_factor=440)
    return rows


def admission_rows(seed: int = 0, scales: Sequence[int] = (64, 256),
                   apps_factor: int = 440,
                   decide_samples: int = 4000) -> List[Dict]:
    """Admission fast-path microbench: one row per scale comparing the
    vectorized arrival path (array ledger + chain-template decision cache)
    against the retained scalar reference loop on the identical request
    stream.

    Two measurements per row, both with GC disabled during timing:

    * **end to end** — per-arrival ``place()`` wall time (p50/p99,
      arrivals/sec) for each mode on its own engine.  The commit
      bookkeeping (registry, journal, reverse indexes) is identical by
      design in both modes, so this ratio is bounded by the shared tail.
    * **decision phase** — ``decide_scalar`` vs ``_decide`` interleaved on
      the same fully warmed engine (identical occupancy), probing a
      deterministic slice of the stream.  This isolates the part the
      vectorization actually replaces; the CI ≥5× speedup gate rides it.

    Every probe asserts decision parity, and the two end-to-end engines
    must agree app-for-app on placement — the admission rows double as a
    scalar↔vector behavior-parity harness at planetary scale."""
    import gc
    import statistics

    import numpy as np

    from repro.core import PlacementEngine, build_paper_topology, sample_requests

    rows: List[Dict] = []
    for scale in scales:
        topo = build_paper_topology(scale=scale)
        reqs = sample_requests(topo, apps_factor * scale,
                               np.random.default_rng(seed))
        per: Dict[str, Dict] = {}
        engines: Dict[str, PlacementEngine] = {}
        gc_was = gc.isenabled()
        for mode in ("scalar", "vector"):
            eng = PlacementEngine(topo, admission_mode=mode)
            times: List[float] = []
            gc.disable()
            try:
                t_run = time.perf_counter()
                for r in reqs:
                    t0 = time.perf_counter()
                    eng.place(r)
                    times.append(time.perf_counter() - t0)
                total = time.perf_counter() - t_run
            finally:
                if gc_was:
                    gc.enable()
            times.sort()
            per[mode] = {
                "p50": times[len(times) // 2],
                "p99": times[int(len(times) * 0.99)],
                "total": total,
            }
            engines[mode] = eng
        es, ev = engines["scalar"], engines["vector"]
        assert len(es.placed) == len(ev.placed), "admission parity: counts"
        assert all(es.placed[r].candidate.node.node_id
                   == ev.placed[r].candidate.node.node_id
                   for r in es.placed), "admission parity: placements"
        assert es.node_used == ev.node_used, "admission parity: ledgers"
        # Decision phase on the warmed vector engine: both functions are
        # pure (no occupancy mutation), so interleaving them probes the
        # same state.
        step = max(1, len(reqs) // decide_samples)
        t_sc: List[float] = []
        t_vec: List[float] = []
        gc.disable()
        try:
            for r in reqs[::step]:
                t0 = time.perf_counter()
                a = ev.decide_scalar(r)
                t1 = time.perf_counter()
                b = ev._decide(r)
                t2 = time.perf_counter()
                t_sc.append(t1 - t0)
                t_vec.append(t2 - t1)
                assert (a is None) == (b is None), "decide parity"
                if a is not None:
                    assert a == b, "decide parity: candidate"
        finally:
            if gc_was:
                gc.enable()
        d50_s = statistics.median(t_sc)
        d50_v = statistics.median(t_vec)
        rows.append({
            "benchmark": "admission",
            "scenario": "admission-fast-path",
            "policy": "engine",
            "seed": seed,
            "scale": scale,
            "arrivals": len(reqs),
            "placed": len(ev.placed),
            "rejected": ev.rejected_total,
            "p50_place_s": round(per["vector"]["p50"], 9),
            "p99_place_s": round(per["vector"]["p99"], 9),
            "p50_place_scalar_s": round(per["scalar"]["p50"], 9),
            "p99_place_scalar_s": round(per["scalar"]["p99"], 9),
            "arrivals_per_s": round(len(reqs) / per["vector"]["total"], 1),
            "arrivals_per_s_scalar": round(
                len(reqs) / per["scalar"]["total"], 1),
            "place_speedup_p50": round(
                per["scalar"]["p50"] / max(per["vector"]["p50"], 1e-12), 2),
            "decide_p50_scalar_s": round(d50_s, 9),
            "decide_p50_vector_s": round(d50_v, 9),
            "decide_speedup_p50": round(d50_s / max(d50_v, 1e-12), 2),
            "decide_probes": len(t_sc),
        })
    return rows


def serving_rows(seed: int = 0, scales: Sequence[int] = (1, 8)) -> List[Dict]:
    """Serving-workload acceptance rows: the `serving-fleet` scenario under
    each forced migration strategy (plus the backend's auto choice) at ×1
    and ×8.  Each row carries the run's `serving` summary (token
    conservation totals, tokens_per_s, p99 token latency, completed
    migrations by strategy) plus the mean downtime of the *serving* moves
    specifically, so the driver can gate kv-ship beating replay on
    decode-heavy sessions: zero recomputed tokens at no worse migration
    downtime, at every scale."""
    rows: List[Dict] = []
    for scale in scales:
        for st in (None, "drain", "replay", "kv-ship"):
            kwargs: Dict = {}
            if scale != 1:
                kwargs["scale"] = scale
            if st is not None:
                kwargs["strategy"] = st
            row = _cell("serving-fleet", "greedy", seed, with_ticks=True,
                        scenario_kwargs=kwargs)
            migs = row.pop("migrations_series", [])
            row.pop("ticks_series", None)
            # Serving moves are the records the backend stamped a strategy
            # on; background batch moves carry none.
            done = [m for m in migs
                    if m.get("strategy") and m.get("outcome") == "completed"]
            dts = [m["downtime_s"] for m in done]
            row["benchmark"] = "serving"
            row["forced_strategy"] = st or "auto"
            row["serving_migrations_completed"] = len(done)
            row["mean_serving_downtime_s"] = (
                round(sum(dts) / len(dts), 6) if dts else None)
            rows.append(row)
    return rows


def smoke(seed: int = 0, scale: int = 2) -> List[Dict]:
    """CI sanity slice: fast cells with every moving part exercised
    (request streams, in-flight migrations, adaptive switching, the
    decomposed and incremental planners at topology scale ×``scale``, a
    backbone cut, and the elastic bridge).  The incremental cell doubles
    as the solver microbenchmark: CI asserts its warm-start hit-rate is
    > 0.  The bridge cells are gated too: the site-outage pair must agree
    on fingerprints between the simulated and flat backends (the
    no-declared-state fallback is the flat model), and the
    hetero-expansion cell must show nonzero byte-derived snapshot/restore
    phase times.  The SLO cell runs the adaptive ladder with a zero
    latency budget (so it falls off the exact tier immediately) under an
    unreachable satisfaction objective: CI asserts burn-rate breaches
    fire AND pull the ladder back toward MILP (slo_escalations > 0) —
    the observe → act loop end to end.  At ``scale`` ≥ 16 (where the
    paper topology crosses `HierarchicalPolicy`'s 4000-node activation
    gate) a hierarchical cell rides along; the driver gates its
    fingerprint against the incremental cell's and budgets the ×scale
    steady tick."""
    from repro.fleet import FlatStateBackend, SimulatedElasticBackend, SloConfig

    hierarchy = [] if scale < 16 else [
        _cell("paper-steady-state", "hierarchical", seed, with_ticks=False,
              scenario_kwargs={"scale": scale, "n_arrivals": 250 * scale}),
    ]
    return [
        _cell("paper-steady-state", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 250}),
        _cell("diurnal-streams", "adaptive", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 200}),
        _cell("backbone-cut", "milp", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 200}),
        _cell("paper-steady-state", "decomposed", seed, with_ticks=False,
              scenario_kwargs={"scale": scale, "n_arrivals": 250 * scale}),
        _cell("paper-steady-state", "incremental", seed, with_ticks=False,
              scenario_kwargs={"scale": scale, "n_arrivals": 250 * scale}),
        *hierarchy,
        # Elastic-bridge smoke: simulated-vs-flat parity on site-outage …
        _cell("site-outage", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 150}),
        _cell("site-outage", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 150},
              backend=FlatStateBackend(64.0)),
        # … and byte-derived phase timings on declared-state jobs.
        _cell("hetero-expansion", "greedy", seed, with_ticks=False),
        # Admission-mode parity smoke: the same cell as the first row but
        # with the scalar reference admission loop — the driver gates the
        # two fingerprints bit-identical (the vectorized fast path is pure
        # mechanism).
        _cell("paper-steady-state", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 250},
              config_kwargs={"admission_mode": "scalar"}),
        # SLO observe→act: breaches must escalate the adaptive ladder.
        _cell("site-outage", "adaptive", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 150},
              policy_kwargs={"budget_s": 0.0},
              slo=SloConfig(satisfaction_objective=1.0,
                            satisfaction_budget_per_tick=0.01,
                            cooldown_s=100.0)),
        # Calibration smoke: the backend's real byte counts are 4× the flat
        # 64 MB pricing belief.  With ``cost_feedback`` off the ledger must
        # catch the miscalibration (drift detectors fire); with it on the
        # predictions come from the backend's own size model and the
        # downtime error collapses — while the fingerprint stays
        # bit-identical to the off cell (the ledger is behavior-neutral).
        _cell("node-outage", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 150},
              backend=SimulatedElasticBackend(default_state_mb=256.0)),
        _cell("node-outage", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_arrivals": 150},
              backend=SimulatedElasticBackend(default_state_mb=256.0),
              config_kwargs={"cost_feedback": True}),
        # Serving smoke: a compact serving-fleet cell with a flash crowd
        # landing mid-reconfiguration and kv-ship forced fleet-wide.  The
        # driver gates token conservation with zero cancellations, at
        # least one completed kv-ship migration (echoed by the calibration
        # ledger's per-strategy counts), and a reported p99 token latency.
        _cell("serving-fleet", "greedy", seed, with_ticks=False,
              scenario_kwargs={"n_background": 100, "sessions_per_app": 8,
                               "flash": True, "strategy": "kv-ship"}),
    ]


def calibration_rows(seed: int = 0) -> List[Dict]:
    """The ISSUE's calibration acceptance pair: hetero-expansion (jobs
    declare 1536 MB of state — 24× the flat 64 MB belief) priced blind vs
    with the self-correcting cost model (`RuntimeConfig.cost_feedback`).
    The driver gates p90(calib_downtime_err) dropping ≥5× feedback-on and
    records both rows in BENCH_fleet.json."""
    from repro.fleet import MigrationCostModel

    return [
        _cell("hetero-expansion", "greedy", seed, with_ticks=False),
        _cell("hetero-expansion", "greedy", seed, with_ticks=False,
              policy_kwargs={"cost_model": MigrationCostModel()},
              config_kwargs={"cost_feedback": True}),
    ]


def _fmt_ratio(v) -> str:
    from repro.fleet.obs.metrics import fmt_ratio  # late: needs PYTHONPATH=src
    return fmt_ratio(v)


def run(seed: int = 0) -> List[str]:
    """CSV rows for the default `benchmarks.run` text mode."""
    out: List[str] = []
    for r in sweep(seed=seed, with_ticks=False):
        out.append(
            f"fleet_{r['scenario']},policy={r['policy']},"
            f"arrivals={r['arrivals']},admitted={r['admitted']},"
            f"rejected={r['rejected']},moves={r['moves']},"
            f"mean_ratio={_fmt_ratio(r['mean_moved_ratio'])},"
            f"mean_ratio_w={_fmt_ratio(r['mean_moved_ratio_weighted'])},"
            f"gain={r['total_gain']:.3f},"
            f"solver_s={r['mean_solver_time_s']:.4f},"
            f"migrations={r['migrations_completed']}/{r['migrations_started']},"
            f"aborted={r['migrations_aborted']},"
            f"mig_dur_s={_fmt_ratio(r['mean_migration_duration_s'])},"
            f"downtime_s={r['total_downtime_s']:.1f},"
            f"arr_inflight={r['arrivals_inflight']},"
            f"wall_s={r['wall_s']:.2f}"
        )
    return out
