"""Fleet-scheduler benchmark: the paper's technique driving a TPU pod fleet.

Builds a heterogeneous fleet (pods at different $/chip-h), submits a job
mix derived from the dry-run roofline table, and reports admission,
utilization, and the reconfiguration gain — the TPU instantiation of
fig. 5."""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.core.cluster import (
    FleetScheduler,
    JobSpec,
    PodSpec,
    build_fleet_topology,
    jobs_from_dryrun,
)


def run() -> List[str]:
    rows: List[str] = []
    pods = [PodSpec(f"pod{i}", 256, price, gen) for i, (price, gen) in
            enumerate([(1.2, "v5e")] * 4 + [(0.9, "v5e-spot")] * 2 + [(2.1, "v5p")] * 2)]
    topo = build_fleet_topology(pods)
    sched = FleetScheduler(topo, reconfig_every=8, window=24)

    results_path = "results/dryrun_single.json"
    if os.path.exists(results_path):
        jobs = jobs_from_dryrun(results_path, chips=64)
    else:  # synthetic mix when the dry-run table is absent
        rng = np.random.default_rng(0)
        jobs = [JobSpec(i, f"arch{i % 5}", "train_4k", chips=64,
                        step_time_s=float(rng.uniform(0.5, 5.0)),
                        step_slo_s=float(rng.uniform(2.0, 10.0)),
                        budget_usd_month=float(rng.uniform(5e4, 3e5)))
                for i in range(30)]
    t0 = time.perf_counter()
    placed = sum(1 for j in jobs if sched.submit(j) is not None)
    dt = time.perf_counter() - t0
    util = sched.utilization()
    rows.append(f"fleet_admission,jobs={len(jobs)},placed={placed},"
                f"rejected={len(jobs) - placed},s={dt:.3f}")
    rows.append("fleet_utilization," + ",".join(
        f"{pod}={u:.2f}" for pod, u in sorted(util.items())))
    res = sched.recon.run(sched.engine.recent(sched.window))
    rows.append(f"fleet_reconfig,window={len(res.window)},moved={res.n_moved},"
                f"gain={res.gain:.4f},mean_ratio={res.mean_moved_ratio:.4f},"
                f"migrations={len(res.migration_steps)}")
    assert sched.engine.occupancy_invariants_ok()
    return rows
