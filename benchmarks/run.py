"""Benchmark driver — one section per paper table/claim.

  bench_paper    — fig. 5(a)/(b) + solver-time claims (§4.2)
  bench_fleet    — fleet-runtime scenario × policy × scale sweep (repro.fleet)
  bench_roofline — §Roofline table from the dry-run artifacts
  bench_kernels  — Pallas kernels (interpret) vs jnp refs

Default mode prints ``name,key=value,...`` CSV rows for every section.
``--json`` runs the fleet sweep (scale ×1 scenario × policy grid, the
×2/×4/×8 solver-scaling sweep with 400×scale windows, a ×32 planetary
slice under the hierarchical planner, ×64/×256 steady-tick rows with
a >100k-app window, the ×64/×256 admission fast-path microbench —
scalar vs vectorized arrival path with a ≥5× decision-phase gate — and
the serving strategy sweep: serving-fleet under each forced migration
strategy at ×1/×8 with a kv-ship-beats-replay gate, zero recomputed
tokens at no worse mean migration downtime) and
writes machine-readable rows to ``BENCH_fleet.json``.  ``--smoke`` runs a CI sanity slice (request
streams + adaptive policy, a backbone cut, the decomposed/incremental
planners at ``--scale`` — plus, at ``--scale`` ≥ 16, the hierarchical
planner with a fingerprint-parity gate and a steady-tick latency budget —
the elastic-bridge cells: simulated-vs-flat fingerprint parity plus
byte-derived phase timings on hetero-expansion, a scalar-vs-vector
admission-mode fingerprint-parity cell (plus, at ``--scale`` ≥ 16, the
admission fast-path microbench with its ≥5× decision-phase speedup and
arrival-throughput gates), an SLO burn-rate → policy-escalation cell, a calibration cell pair (drift detectors must
catch a 4×-miscalibrated size model, ``cost_feedback`` must collapse the
downtime prediction error without perturbing the behavior fingerprint),
a serving-fleet cell (a flash crowd lands mid-reconfiguration with
kv-ship forced: token conservation with zero cancellations, ≥1
completed kv-ship migration, a reported per-token p99),
and a traced run validated against the Chrome trace_event schema) and
exits non-zero on any failure.  ``--trace out.json`` runs one scenario
with the dual-clock span tracer attached and writes a Perfetto-loadable
trace (open in ui.perfetto.dev or chrome://tracing).  ``--report
calibration`` dumps the full calibration ledger — residual summaries,
drift records, and per-move decision provenance — for the
hetero-expansion acceptance pair.
"""

import argparse
import json
import sys
import traceback


def _ratio(v):
    from repro.fleet.obs.metrics import fmt_ratio  # late: needs PYTHONPATH=src
    return fmt_ratio(v)


def _traced_run(scenario: str, policy: str, seed: int, **scenario_kwargs):
    """One scenario run with the span tracer attached → (tracer, telemetry)."""
    from repro.fleet import SpanTracer, build_scenario, get_policy

    spec = build_scenario(scenario, seed=seed, **scenario_kwargs)
    tracer = SpanTracer()
    runtime = spec.make_runtime(get_policy(policy), tracer=tracer)
    tel = runtime.run(spec.event_queue(), scenario=scenario, seed=seed)
    return tracer, tel


def run_trace(out_path: str, scenario: str, policy: str, seed: int) -> int:
    from repro.fleet import validate_trace

    tracer, tel = _traced_run(scenario, policy, seed)
    n = tracer.write(out_path)
    problems = validate_trace(tracer.to_dict())
    print(f"wrote {out_path}: {n} trace events "
          f"({scenario}/{policy}, {len(tel.ticks)} ticks, "
          f"{tel.counters['migrations_completed']} migrations completed)")
    for p in problems:
        print(f"  INVALID: {p}")
    if not problems:
        print("  trace schema: OK — load in ui.perfetto.dev / chrome://tracing")
    return 1 if problems else 0


def run_json(out_path: str, seed: int) -> int:
    from benchmarks.bench_fleet import (
        DEFAULT_POLICIES,
        SCALE_SWEEP_POLICIES,
        SCALE_SWEEP_SCALES,
        admission_rows,
        calibration_rows,
        planetary_rows,
        scale_sweep,
        serving_rows,
        steady_tick_rows,
        sweep,
    )

    rows = sweep(seed=seed)
    scaled = scale_sweep(seed=seed)
    # Planetary slice: the ×32 scenario sweep (hierarchical planner vs its
    # flat equivalent and the greedy floor) plus the ×32/×64/×256
    # steady-tick microbench with the >100k-app window.
    scaled += scale_sweep(scales=(32,), scenarios=("paper-steady-state",),
                          policies=("incremental", "hierarchical", "greedy"),
                          seed=seed, with_ticks=False)
    steady = steady_tick_rows(seed=seed)
    steady += steady_tick_rows((32,), seed=seed,
                               policies=("decomposed", "incremental",
                                         "hierarchical"))
    steady += planetary_rows(seed=seed)
    calib = calibration_rows(seed=seed)
    admission = admission_rows(seed=seed)
    serving = serving_rows(seed=seed)
    doc = {
        "benchmark": "fleet_runtime",
        "seed": seed,
        "policies": list(DEFAULT_POLICIES),
        "scale_sweep": {"scales": list(SCALE_SWEEP_SCALES) + [32],
                        "policies": list(SCALE_SWEEP_POLICIES)},
        "rows": rows + scaled,
        "steady_tick": steady,
        "calibration": calib,
        "admission": admission,
        "serving": serving,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {out_path}: {len(rows)} scale-1 rows + "
          f"{len(scaled)} scale-sweep rows + {len(steady)} steady-tick rows + "
          f"{len(calib)} calibration rows + {len(admission)} admission rows + "
          f"{len(serving)} serving rows")
    ok = 0
    # Serving acceptance: at each scale kv-ship must beat replay on
    # decode-heavy sessions — zero recomputed tokens (where replay must
    # show the recompute cost it pays) at no worse mean serving-migration
    # downtime.
    srv_by_scale = {}
    for r in serving:
        srv_by_scale.setdefault(r["scale"], {})[r["forced_strategy"]] = r
    for sc in sorted(srv_by_scale):
        cells = srv_by_scale[sc]
        for st in ("auto", "drain", "replay", "kv-ship"):
            r = cells.get(st)
            if r is None:
                continue
            s = r.get("serving") or {}
            dt = r["mean_serving_downtime_s"]
            print(f"  serving x{sc} {st:8s}: "
                  f"tok/s={s.get('tokens_per_s', 0):.2f} "
                  f"p99={s.get('p99_token_latency_s', 0):.4f}s "
                  f"rec={s.get('tokens_recomputed', 0):6d} "
                  f"cancel={s.get('tokens_cancelled', 0):4d} "
                  f"migs={r['serving_migrations_completed']:3d} "
                  f"mean_dt={dt if dt is not None else float('nan'):.3f}s")
        kv, rp = cells.get("kv-ship"), cells.get("replay")
        kv_s = (kv or {}).get("serving") or {}
        rp_s = (rp or {}).get("serving") or {}
        good = (kv is not None and rp is not None
                and kv_s.get("tokens_recomputed") == 0
                and rp_s.get("tokens_recomputed", 0) > 0
                and kv["mean_serving_downtime_s"] is not None
                and rp["mean_serving_downtime_s"] is not None
                and kv["mean_serving_downtime_s"]
                <= rp["mean_serving_downtime_s"])
        print(f"  serving x{sc}: kv-ship rec==0 & replay rec>0 & "
              f"kv downtime <= replay [{'OK' if good else 'MISS'}]")
        ok |= 0 if good else 1
    # Admission fast-path acceptance: the vectorized decision phase must
    # beat the scalar reference ≥5× at p50 on the planetary cells (the
    # rows assert scalar↔vector placement parity internally; end-to-end
    # p50/p99 ride along as evidence columns).
    for r in admission:
        good = r["decide_speedup_p50"] >= 5.0
        print(f"  admission x{r['scale']}: {r['arrivals']} arrivals, "
              f"place p50 {r['p50_place_scalar_s'] * 1e6:.1f}us -> "
              f"{r['p50_place_s'] * 1e6:.1f}us "
              f"({r['place_speedup_p50']:.1f}x e2e), decide p50 "
              f"{r['decide_p50_scalar_s'] * 1e6:.1f}us -> "
              f"{r['decide_p50_vector_s'] * 1e6:.1f}us "
              f"({r['decide_speedup_p50']:.1f}x) "
              f"[>=5x: {'OK' if good else 'MISS'}], "
              f"{r['arrivals_per_s']:.0f} arrivals/s")
        ok |= 0 if good else 1
    # Calibration acceptance (ISSUE): on hetero-expansion the p90 relative
    # error of predicted vs measured migration downtime must drop ≥5× with
    # the self-correcting cost model (`RuntimeConfig.cost_feedback`) on.
    c_off = next((r for r in calib if not r["cost_feedback"]), None)
    c_on = next((r for r in calib if r["cost_feedback"]), None)
    if c_off and c_on and c_off["p90_calib_downtime_err"] is not None \
            and c_on["p90_calib_downtime_err"] is not None:
        ratio = c_off["p90_calib_downtime_err"] / max(
            c_on["p90_calib_downtime_err"], 1e-9)
        good = ratio >= 5.0
        print(f"  calibration hetero-expansion: p90 downtime err "
              f"{c_off['p90_calib_downtime_err']:.4f} → "
              f"{c_on['p90_calib_downtime_err']:.4f} ({ratio:.1f}x) "
              f"[>=5x: {'OK' if good else 'MISS'}]")
        ok |= 0 if good else 1
    else:
        print("  calibration hetero-expansion pair missing p90 columns [MISS]")
        ok |= 1
    for sc in sorted({r["scale"] for r in steady}):
        by_pol = {r["policy"]: r for r in steady if r["scale"] == sc}
        cols = " ".join(
            f"{pol}={row['mean_steady_tick_s'] * 1e3:.1f}ms"
            for pol, row in by_pol.items())
        extra = ""
        if "decomposed" in by_pol and "incremental" in by_pol:
            inc = by_pol["incremental"]
            ratio = by_pol["decomposed"]["mean_steady_tick_s"] / max(
                inc["mean_steady_tick_s"], 1e-9)
            extra = (f" ({ratio:.1f}x, reused {inc['regions_reused_last']}/"
                     f"{inc['regions_reused_last'] + inc['regions_solved_last']})")
        if sc >= 32:
            # Planetary acceptance: steady ticks under 100 ms at ×32+.
            p50s = {pol: row["p50_steady_tick_s"]
                    for pol, row in by_pol.items()
                    if pol in ("incremental", "hierarchical")}
            good = p50s and all(v < 0.1 for v in p50s.values())
            extra += f"  [p50 < 100ms: {'OK' if good else 'MISS'}]"
            ok |= 0 if good else 1
        print(f"  steady-tick x{sc}: {cols}{extra}")
    # Incremental-vs-full acceptance: identical behavior fingerprints at
    # scale ×1 (deterministic policies), the hierarchical planner's
    # fingerprint parity wherever its flat equivalent ran, and the ×4
    # window-1600 sweep's planning-latency ratio.
    by_cell = {(r["scenario"], r["scale"], r["policy"]): r
               for r in rows + scaled}
    for r in rows + scaled:
        flag = ""
        if (r["scenario"] == "paper-steady-state" and r["policy"] == "milp"
                and r["scale"] == 1):
            # Paper fig. 5(b): moved-app mean X+Y ≈ 1.96.
            in_env = (r["mean_moved_ratio"] is not None
                      and abs(r["mean_moved_ratio"] - 1.96) <= 0.15)
            flag = f"  [paper envelope ±0.15: {'OK' if in_env else 'MISS'}]"
            ok |= 0 if in_env else 1
        if r["policy"] == "incremental":
            dec = by_cell.get((r["scenario"], r["scale"], "decomposed"))
            if dec is not None:
                if r["scale"] == 1:
                    same = r["fingerprint"] == dec["fingerprint"]
                    flag += f"  [fp == decomposed: {'OK' if same else 'MISS'}]"
                    ok |= 0 if same else 1
                elif dec["mean_solver_time_s"] > 0:
                    speedup = dec["mean_solver_time_s"] / max(
                        r["mean_solver_time_s"], 1e-9)
                    flag += f"  [vs decomposed: {speedup:.1f}x]"
        if r["policy"] == "hierarchical":
            inc = by_cell.get((r["scenario"], r["scale"], "incremental"))
            if inc is not None:
                same = r["fingerprint"] == inc["fingerprint"]
                flag += f"  [fp == incremental: {'OK' if same else 'MISS'}]"
                ok |= 0 if same else 1
        print(f"  {r['scenario']:28s} {r['policy']:11s} x{r['scale']:<2d} "
              f"ratio={_ratio(r['mean_moved_ratio'])} "
              f"ratio_w={_ratio(r['mean_moved_ratio_weighted'])} "
              f"moves={r['moves']:4d} "
              f"migs={r['migrations_completed']:3d}/{r['migrations_started']:3d} "
              f"abort={r['migrations_aborted']:2d} "
              f"solver_max={r['max_solver_time_s']:7.3f}s "
              f"gain={r['total_gain']:8.3f} wall={r['wall_s']:.2f}s{flag}")
    return ok


def run_smoke(seed: int, scale: int) -> int:
    from benchmarks.bench_fleet import smoke

    rows = smoke(seed=seed, scale=scale)
    bad = 0
    for r in rows:
        ok = r["admitted"] > 0 and r["ticks"] > 0
        if r["scenario"] == "backbone-cut":
            ok = ok and r["link_failures"] > 0
        if r["policy"] == "incremental":
            # Solver microbenchmark gate: the warm-start path must be live.
            ok = ok and r["warm_start_hits"] > 0
        if r["scenario"] == "hetero-expansion":
            # Elastic-bridge gate: declared-state jobs must execute real
            # snapshot → transfer → restore pipelines with byte-derived
            # phase times.
            ok = (ok and r["migrations_completed"] > 0
                  and r["total_snapshot_s"] > 0 and r["total_restore_s"] > 0)
        if r["policy"] == "adaptive" and r["scenario"] == "site-outage":
            # SLO observe→act gate: burn-rate breaches must fire AND pull
            # the adaptive ladder back toward the exact tier.
            ok = ok and r["slo_breaches"] > 0 and r["slo_escalations"] > 0
        bad |= 0 if ok else 1
        print(f"  {r['scenario']:28s} {r['policy']:11s} x{r['scale']:<2d} "
              f"backend={r['backend']:9s} "
              f"admitted={r['admitted']} ticks={r['ticks']} "
              f"migs={r['migrations_completed']} "
              f"ratio={_ratio(r['mean_moved_ratio'])} "
              f"warm={r['warm_start_hits']}/{r['regions_solved']} "
              f"reused={r['regions_reused']} "
              f"phases={r['total_snapshot_s']:.2f}/"
              f"{r['total_transfer_s']:.2f}/{r['total_restore_s']:.2f}s "
              f"slo={r['slo_breaches']}b/{r['slo_escalations']}e "
              f"[{'OK' if ok else 'FAIL'}]")
    if scale >= 16:
        # Hierarchical parity gate: above the 4000-node activation gate
        # the region-of-regions planner must still fingerprint identically
        # to the flat incremental planner on the same cell.
        pair = {r["policy"]: r["fingerprint"] for r in rows
                if r["scenario"] == "paper-steady-state"
                and r["scale"] == scale
                and r["policy"] in ("incremental", "hierarchical")}
        if len(pair) == 2:
            same = pair["hierarchical"] == pair["incremental"]
            print(f"  hierarchical parity x{scale} (fp == incremental): "
                  f"{'OK' if same else 'FAIL'}")
            bad |= 0 if same else 1
        else:
            print("  hierarchical parity pair missing from smoke rows [FAIL]")
            bad |= 1
        # Planetary steady-tick budget gate: quiet ticks at ×scale must
        # come in under the 100 ms acceptance ceiling.
        from benchmarks.bench_fleet import steady_tick_rows

        st = steady_tick_rows((scale,), seed=seed,
                              policies=("incremental", "hierarchical"))
        worst = max(r["p50_steady_tick_s"] for r in st)
        ok = worst < 0.1
        cols = " ".join(f"{r['policy']}={r['p50_steady_tick_s'] * 1e3:.1f}ms"
                        for r in st)
        print(f"  steady-tick budget x{scale}: {cols} p50<100ms "
              f"[{'OK' if ok else 'FAIL'}]")
        bad |= 0 if ok else 1
        # Admission fast-path gates at planetary scale: the vectorized
        # decision phase (the part the array ledger + decision cache
        # replace) must beat the retained scalar reference ≥5× at p50,
        # and end-to-end arrival throughput must clear the budget.  The
        # cell also asserts scalar↔vector placement parity internally.
        from benchmarks.bench_fleet import admission_rows

        ad = admission_rows(seed=seed, scales=(scale,),
                            decide_samples=2000)[0]
        dec_ok = ad["decide_speedup_p50"] >= 5.0
        thr_ok = ad["arrivals_per_s"] >= 10_000
        ok = dec_ok and thr_ok
        print(f"  admission fast path x{scale}: decide p50 "
              f"{ad['decide_p50_scalar_s'] * 1e6:.1f}us -> "
              f"{ad['decide_p50_vector_s'] * 1e6:.1f}us "
              f"({ad['decide_speedup_p50']:.1f}x) "
              f"[>=5x: {'OK' if dec_ok else 'FAIL'}], "
              f"{ad['arrivals_per_s']:.0f} arrivals/s "
              f"(scalar {ad['arrivals_per_s_scalar']:.0f}) "
              f"[>=10k/s: {'OK' if thr_ok else 'FAIL'}] "
              f"[{'OK' if ok else 'FAIL'}]")
        bad |= 0 if ok else 1
    # Elastic-bridge parity gate: the simulated backend's no-declared-state
    # fallback must be behavior-identical to the flat executor model.
    pair = {r["backend"]: r["fingerprint"] for r in rows
            if r["scenario"] == "site-outage" and r["policy"] == "greedy"}
    if len(pair) == 2:
        same = pair["simulated"] == pair["flat"]
        print(f"  bridge parity (site-outage simulated vs flat): "
              f"{'OK' if same else 'FAIL'}")
        bad |= 0 if same else 1
    else:
        print("  bridge parity pair missing from smoke rows [FAIL]")
        bad |= 1
    # Admission-mode parity gate: the vectorized admission fast path must
    # fingerprint bit-identically to the retained scalar reference loop on
    # the same scenario cell (pure mechanism, zero behavior drift).
    pair = {r["admission_mode"]: r["fingerprint"] for r in rows
            if r["scenario"] == "paper-steady-state"
            and r["policy"] == "greedy" and r["scale"] == 1}
    if len(pair) == 2:
        same = pair["vector"] == pair["scalar"]
        print(f"  admission parity (scalar vs vector fingerprint): "
              f"{'OK' if same else 'FAIL'}")
        bad |= 0 if same else 1
    else:
        print("  admission parity pair missing from smoke rows [FAIL]")
        bad |= 1
    # Calibration gates: on the node-outage pair (backend bytes 4× the
    # flat pricing belief) the ledger must flag the miscalibration
    # (drift detectors fire feedback-off), the backend-informed
    # predictions must shrink the p90 downtime error, and turning the
    # feedback knob must NOT perturb the behavior fingerprint.
    pair = {bool(r["cost_feedback"]): r for r in rows
            if r["scenario"] == "node-outage" and r["policy"] == "greedy"}
    if len(pair) == 2:
        off, on = pair[False], pair[True]
        drift_ok = off["calib_drifts"] > 0
        p_off, p_on = off["p90_calib_downtime_err"], on["p90_calib_downtime_err"]
        conv_ok = p_off is not None and p_on is not None and p_on < p_off
        fp_ok = off["fingerprint"] == on["fingerprint"]
        ok = drift_ok and conv_ok and fp_ok
        print(f"  calibration smoke (node-outage 4x bytes): "
              f"drifts={off['calib_drifts']} "
              f"p90_err={p_off}->{p_on} "
              f"drift fired: {'OK' if drift_ok else 'FAIL'}, "
              f"err shrank: {'OK' if conv_ok else 'FAIL'}, "
              f"fp unperturbed: {'OK' if fp_ok else 'FAIL'} "
              f"[{'OK' if ok else 'FAIL'}]")
        bad |= 0 if ok else 1
    else:
        print("  calibration smoke pair missing from smoke rows [FAIL]")
        bad |= 1
    # Serving gate: the flash-crowd serving-fleet cell forces kv-ship
    # fleet-wide; every submitted token must be decoded (conservation with
    # zero cancellations), at least one kv-ship migration must complete
    # mid-decode (echoed by the calibration ledger's per-strategy counts),
    # and the per-token p99 must be reported.
    srow = next((r for r in rows if r["scenario"] == "serving-fleet"), None)
    if srow is not None and srow.get("serving"):
        s = srow["serving"]
        conserve_ok = (s["tokens_decoded"] + s["tokens_cancelled"]
                       == s["tokens_submitted"])
        lossless_ok = s["tokens_cancelled"] == 0
        mig_ok = s["migrations"].get("kv-ship", 0) >= 1
        calib_ok = (srow.get("calib_strategies") or {}).get("kv-ship", 0) >= 1
        p99_ok = s["p99_token_latency_s"] > 0
        ok = conserve_ok and lossless_ok and mig_ok and calib_ok and p99_ok
        print(f"  serving smoke (serving-fleet kv-ship flash): "
              f"tokens={s['tokens_decoded']}/{s['tokens_submitted']} "
              f"cancel={s['tokens_cancelled']} "
              f"kv_migs={s['migrations'].get('kv-ship', 0)} "
              f"p99={s['p99_token_latency_s']:.4f}s "
              f"conserved: {'OK' if conserve_ok else 'FAIL'}, "
              f"lossless: {'OK' if lossless_ok else 'FAIL'}, "
              f"kv-ship completed: {'OK' if mig_ok else 'FAIL'}, "
              f"calib strategy counted: {'OK' if calib_ok else 'FAIL'} "
              f"[{'OK' if ok else 'FAIL'}]")
        bad |= 0 if ok else 1
    else:
        print("  serving smoke row missing serving summary [FAIL]")
        bad |= 1
    # Trace smoke: a traced run must export a schema-valid Chrome
    # trace_event document with ≥1 tick-phase span and ≥1 migration whose
    # snapshot/copy/restore phases nest inside it (validate_trace checks
    # all of this), bit-identical in fingerprint to the untraced run.
    from repro.fleet import validate_trace

    from repro.fleet import build_scenario, get_policy

    tracer, tel = _traced_run("site-outage", "incremental", seed,
                              n_arrivals=150)
    doc = tracer.to_dict()
    problems = validate_trace(doc)
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    n_tick = sum(1 for e in spans if e["name"] == "tick")
    n_mig = sum(1 for e in spans if e["name"].startswith("migrate"))
    spec = build_scenario("site-outage", seed=seed, n_arrivals=150)
    plain = spec.make_runtime(get_policy("incremental")).run(
        spec.event_queue(), scenario="site-outage", seed=seed)
    neutral = tel.fingerprint() == plain.fingerprint()
    ok = not problems and n_tick > 0 and n_mig > 0 and neutral
    print(f"  trace smoke (site-outage/incremental): {len(spans)} spans, "
          f"{n_tick} ticks, {n_mig} migrations, "
          f"traced fp == untraced: {'OK' if neutral else 'FAIL'} "
          f"[{'OK' if ok else 'FAIL'}]")
    for p in problems:
        print(f"    INVALID: {p}")
    bad |= 0 if ok else 1
    return bad


def run_report(seed: int) -> int:
    """``--report calibration``: dump the full calibration ledger for the
    hetero-expansion acceptance pair — residual summaries, every
    `CalibrationDrift` record, and the per-move decision provenance
    (`MoveProvenance`) explaining *why* each committed move won."""
    from repro.fleet import MigrationCostModel, build_scenario, get_policy

    for feedback in (False, True):
        spec = build_scenario("hetero-expansion", seed=seed)
        spec.config.cost_feedback = feedback
        policy = (get_policy("greedy", cost_model=MigrationCostModel())
                  if feedback else get_policy("greedy"))
        runtime = spec.make_runtime(policy)
        tel = runtime.run(spec.event_queue(), scenario="hetero-expansion",
                          seed=seed)
        rep = tel.calibration
        hist = runtime.metrics.histogram("calibration/downtime_rel_err")
        print(f"# calibration report: hetero-expansion/greedy "
              f"cost_feedback={'on' if feedback else 'off'}")
        print(f"  joined={rep['samples']} excluded={rep['excluded']} "
              f"unmatched={rep['unmatched']} pending={rep['pending']} "
              f"learned_apps={rep['learned_apps']} "
              f"contention_s={rep['contention_s_total']:.3f}")
        print(f"  downtime_rel_err p50={hist.percentile(0.5):.4f} "
              f"p90={hist.percentile(0.9):.4f}")
        for dr in rep["drifts"]:
            print(f"  drift {json.dumps(dr, sort_keys=True)}")
        prov = rep["provenance"]
        print(f"  provenance: {prov['moves']} moves, "
              f"{prov['price_binding']} price-binding, "
              f"{prov['budget_binding']} budget-binding")
        for p in prov["records"]:
            print(f"  why {json.dumps(p, sort_keys=True)}")
    return 0


def run_csv(seed: int = 0) -> int:
    from benchmarks import bench_fleet, bench_kernels, bench_paper, bench_roofline

    sections = [
        ("paper", bench_paper.run),
        ("fleet", lambda: bench_fleet.run(seed=seed)),
        ("roofline", bench_roofline.run),
        ("kernels", bench_kernels.run),
    ]
    failed = 0
    for name, fn in sections:
        print(f"# === {name} ===")
        try:
            for row in fn():
                print(row)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},ERROR")
    return failed


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="run the fleet sweep and write BENCH_fleet.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI sanity slice of the fleet sweep")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="output path for --json (default: BENCH_fleet.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=int, default=2,
                    help="topology scale for the --smoke planner cells "
                         "(≥16 adds the hierarchical parity + steady-tick "
                         "budget gates)")
    ap.add_argument("--report", choices=("calibration",),
                    help="dump one observability report (calibration: the "
                         "predicted-vs-actual ledger + decision provenance "
                         "for the hetero-expansion pair)")
    ap.add_argument("--trace", metavar="OUT",
                    help="run one traced scenario and write Chrome/Perfetto "
                         "trace_event JSON to OUT")
    ap.add_argument("--trace-scenario", default="site-outage",
                    help="scenario for --trace (default: site-outage)")
    ap.add_argument("--trace-policy", default="incremental",
                    help="policy for --trace (default: incremental)")
    args = ap.parse_args()
    if args.report:
        sys.exit(run_report(args.seed))
    if args.trace:
        sys.exit(run_trace(args.trace, args.trace_scenario,
                           args.trace_policy, args.seed))
    if args.smoke:
        sys.exit(run_smoke(args.seed, args.scale))
    sys.exit(run_json(args.out, args.seed) if args.json else run_csv(args.seed))


if __name__ == "__main__":
    main()
