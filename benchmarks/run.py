"""Benchmark driver — one section per paper table/claim.

  bench_paper    — fig. 5(a)/(b) + solver-time claims (§4.2)
  bench_fleet    — the technique on a TPU pod fleet (TPU fig. 5 analogue)
  bench_roofline — §Roofline table from the dry-run artifacts
  bench_kernels  — Pallas kernels (interpret) vs jnp refs

Prints ``name,key=value,...`` CSV rows.
"""

import sys
import traceback


def main() -> None:
    from benchmarks import bench_fleet, bench_kernels, bench_paper, bench_roofline

    sections = [
        ("paper", bench_paper.run),
        ("fleet", bench_fleet.run),
        ("roofline", bench_roofline.run),
        ("kernels", bench_kernels.run),
    ]
    failed = 0
    for name, fn in sections:
        print(f"# === {name} ===")
        try:
            for row in fn():
                print(row)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},ERROR")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
