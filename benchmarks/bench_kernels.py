"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path
timing only; TPU is the perf target) vs the jnp reference, µs/call."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    rows: List[str] = []
    B, S, Hq, Hkv, D = 1, 512, 8, 2, 64
    q = jax.random.normal(KEY, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(KEY, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(KEY, (B, S, Hkv, D), jnp.float32)
    us_p = _time(lambda *a: ops.flash_attention(*a), q, k, v)
    us_r = _time(lambda *a: jax.jit(ref.flash_attention_ref)(*a), q, k, v)
    rows.append(f"flash_attention,us_interpret={us_p:.0f},us_ref={us_r:.0f},"
                f"shape=({B}x{S}x{Hq}x{D})")

    kv_len = jnp.array([S // 2], jnp.int32)
    qd = jax.random.normal(KEY, (1, 1, Hq, D))
    us_p = _time(lambda *a: ops.decode_attention(*a), qd, k, v, kv_len)
    us_r = _time(lambda *a: jax.jit(ref.decode_attention_ref)(*a), qd, k, v, kv_len)
    rows.append(f"decode_attention,us_interpret={us_p:.0f},us_ref={us_r:.0f}")

    x = jax.random.normal(KEY, (4096, 1024))
    sc = jnp.ones((1024,))
    us_p = _time(lambda *a: ops.rms_norm(*a), x, sc)
    us_r = _time(lambda *a: jax.jit(ref.rms_norm_ref)(*a), x, sc)
    rows.append(f"rms_norm,us_interpret={us_p:.0f},us_ref={us_r:.0f}")

    H, P, N = 4, 32, 16
    xs = jax.random.normal(KEY, (1, 256, H, P))
    Bm = jax.random.normal(KEY, (1, 256, N))
    Cm = jax.random.normal(KEY, (1, 256, N))
    dt = jax.nn.softplus(jax.random.normal(KEY, (1, 256, H)))
    Al = jax.random.normal(KEY, (H,)) * 0.5
    Dd = jax.random.normal(KEY, (H,))
    us_p = _time(lambda *a: ops.ssm_scan(*a), xs, Bm, Cm, dt, Al, Dd)
    us_r = _time(lambda *a: jax.jit(ref.ssm_scan_ref)(*a), xs, Bm, Cm, dt, Al, Dd)
    rows.append(f"ssm_scan,us_interpret={us_p:.0f},us_ref={us_r:.0f}")
    return rows
